//! Continuous repartitioning: long-lived streaming jobs over the batch
//! shuffle machinery (the BlobShuffle direction — object-storage
//! repartitioning for stream processing).
//!
//! A [`StreamJob`] consumes an unbounded sequence of input shards from a
//! seeded [`IngestSource`] (configurable arrival rate, burst pattern,
//! Zipf-skewable keys), groups arrivals into fixed windows (*epochs*),
//! and runs each epoch's map→shuffle→reduce through the existing
//! [`ShuffleStrategy`](crate::shuffle::ShuffleStrategy) /
//! [`JobService`] machinery — so a stream inherits everything the batch
//! path already has: pluggable stage topologies, the zero-copy `Block`
//! data plane, per-job fair-share scheduling, lineage recovery, and
//! both the threaded and the deterministic simulation backends (vopr
//! sweeps streams the same way it sweeps sorts).
//!
//! **Epochs pipeline.** Up to [`StreamJob::pipeline_depth`] epochs are
//! open at once: epoch N+1 is submitted (its ingest shards written, its
//! maps admitted under fair-share) while epoch N's reduces drain. Each
//! epoch is its own runtime job, so sealing an epoch retires it —
//! lineage freed, task events drained, store entries swept
//! ([`crate::distfut::RuntimeHandle::retire_job`]) — and the stream's
//! store footprint stays bounded by its pipeline depth, not its history
//! (probed per epoch via
//! [`crate::distfut::RuntimeHandle::store_live_entries_for`]).
//!
//! **Watermark / epoch-seal semantics.** Epochs seal strictly in
//! arrival order; the *watermark* is the count of contiguously sealed
//! epochs. An epoch is sealed once its partitioned output is fully
//! committed and validated — downstream consumers may read everything
//! at or below the watermark.
//!
//! **Latency SLOs.** Each epoch's ingest→sealed latency is the modeled
//! arrival window of its records (`records / arrival_rate` — the last
//! record of a window arrives a full window after the first) plus the
//! measured admit→seal time on the runtime's clock. The distribution
//! (p50/p95/p99, SLO violations) is tracked by
//! [`crate::metrics::LatencyTracker`] and stamped on every sealed
//! epoch's [`JobReport::latency`].
//!
//! **Stream-vs-sort identity.** Every epoch's output is byte-identical
//! to a one-shot batch sort of the same shards: the epoch spec (seed,
//! skew, size) fully determines the input, and output bytes are a pure
//! function of the input regardless of chaos, backend, or how many
//! epochs were in flight. [`StreamJob::verify_batch`] re-runs each
//! epoch as a batch job and checks the checksums; the streaming tests
//! and vopr's `stream` workload assert it on both backends through
//! mid-epoch kills.

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::anyhow;

use crate::coordinator::plan::JobSpec;
use crate::distfut::chaos::ChaosPlan;
use crate::metrics::{LatencyStats, LatencyTracker};
use crate::runtime::Backend;
use crate::service::{JobHandle, JobService, ServiceConfig};
use crate::shuffle::{JobReport, ShuffleJob, ShuffleStrategy, TwoStageMerge};
use crate::sortlib::{Skew, RECORD_SIZE};
use crate::util::rng::stream_at;

/// RNG stream index for per-epoch input seeds, disjoint from the vopr
/// chaos-plan streams (101–104) and the simulator's own draws.
const EPOCH_SEED_STREAM: u64 = 300;

/// Seeded arrival process: how many records each window (epoch)
/// collects, how long the window takes to fill, and which seed
/// generates its shards.
#[derive(Clone, Debug)]
pub struct IngestSource {
    /// Root seed: every epoch's input shards (and therefore its output
    /// bytes) derive deterministically from this.
    pub seed: u64,
    /// Steady-state arrival rate in records/second. `0.0` models an
    /// already-full backlog (windows fill instantly; latency is pure
    /// processing time).
    pub arrival_rate: f64,
    /// Records per window.
    pub epoch_records: u64,
    /// Every `burst_every`-th epoch arrives at `burst_factor ×` the
    /// steady rate (its window fills faster, shrinking the ingest slack
    /// the shuffle can hide behind). `0`: no bursts.
    pub burst_every: usize,
    pub burst_factor: f64,
    /// Key distribution of the arriving records (Zipf-skewable, same
    /// knob as the batch `--skew`).
    pub skew: Skew,
}

impl IngestSource {
    /// A steady uniform-key source (no bursts).
    pub fn new(seed: u64, arrival_rate: f64, epoch_records: u64) -> IngestSource {
        IngestSource {
            seed,
            arrival_rate,
            epoch_records,
            burst_every: 0,
            burst_factor: 1.0,
            skew: Skew::Uniform,
        }
    }

    /// The deterministic input seed of one epoch's shards.
    pub fn epoch_seed(&self, epoch: usize) -> u64 {
        stream_at(self.seed, EPOCH_SEED_STREAM + epoch as u64)
    }

    /// The arrival of one window: record count, modeled fill time, and
    /// the shard seed.
    pub fn arrival(&self, epoch: usize) -> EpochArrival {
        let mut rate = self.arrival_rate;
        if self.burst_every > 0 && (epoch + 1) % self.burst_every == 0 {
            rate *= self.burst_factor.max(1.0);
        }
        let window_secs = if rate > 0.0 {
            self.epoch_records as f64 / rate
        } else {
            0.0
        };
        EpochArrival {
            epoch,
            records: self.epoch_records,
            window_secs,
            seed: self.epoch_seed(epoch),
        }
    }
}

/// One window's worth of arrivals, as modeled by an [`IngestSource`].
#[derive(Clone, Debug)]
pub struct EpochArrival {
    pub epoch: usize,
    pub records: u64,
    /// Modeled time for this window's records to arrive at the source's
    /// (possibly burst-scaled) rate.
    pub window_secs: f64,
    /// Seed the epoch's input shards are generated from.
    pub seed: u64,
}

/// One sealed epoch of a stream.
#[derive(Clone, Debug)]
pub struct EpochReport {
    pub epoch: usize,
    /// The spec the epoch ran under (what a batch-identity check
    /// re-runs).
    pub spec: JobSpec,
    pub records: u64,
    pub checksum: u64,
    /// Modeled ingest window of this epoch's records.
    pub window_secs: f64,
    /// Runtime-clock seconds (relative to stream start) the epoch was
    /// admitted / sealed.
    pub open_secs: f64,
    pub sealed_secs: f64,
    /// Ingest→sealed latency: `window_secs` + (sealed − open).
    pub latency_secs: f64,
    /// Whether this epoch broke the armed SLO.
    pub slo_violated: bool,
    /// Whether the epoch's store entries were fully swept at
    /// retirement (the bounded-footprint invariant).
    pub store_purged: bool,
    /// `Some(true)` once a batch re-run of the same shards produced the
    /// same bytes ([`StreamJob::verify_batch`]); `None` when the check
    /// was not requested.
    pub batch_identical: Option<bool>,
    /// The epoch's full per-job report (stages, validation, recovery,
    /// chaos log; `latency` carries the stream's stats-so-far).
    pub report: JobReport,
}

/// Outcome of a [`StreamJob`] run.
#[derive(Clone, Debug)]
pub struct StreamReport {
    pub name: String,
    pub strategy: String,
    /// Sealed epochs, in watermark order.
    pub epochs: Vec<EpochReport>,
    /// Final ingest→sealed latency distribution (p50/p95/p99 + SLO
    /// violations) over all sealed epochs.
    pub latency: LatencyStats,
    /// Contiguously sealed epochs (epochs seal in order, so this equals
    /// `epochs.len()`; named for the semantics, not the arithmetic).
    pub watermark: usize,
    /// Seconds during which two adjacent epochs were open at once —
    /// summed `max(0, seal(N) − open(N+1))`. Zero means the stream
    /// degenerated to serial batch jobs.
    pub pipeline_overlap_secs: f64,
    /// Most epochs simultaneously open (bounded by the pipeline depth).
    pub max_open_epochs: usize,
    /// Runtime-clock seconds from stream start to the last seal.
    pub total_secs: f64,
    pub total_records: u64,
    pub total_bytes: u64,
}

impl StreamReport {
    /// Whether every sealed epoch validated (sorted, checksummed).
    pub fn all_valid(&self) -> bool {
        self.epochs.iter().all(|e| e.report.validation.valid)
    }

    /// Whether every epoch's store entries were swept at retirement.
    pub fn all_purged(&self) -> bool {
        self.epochs.iter().all(|e| e.store_purged)
    }

    /// Sealed-output throughput over the whole stream.
    pub fn bytes_per_sec(&self) -> f64 {
        self.total_bytes as f64 / self.total_secs.max(1e-9)
    }
}

/// An epoch submitted but not yet sealed.
struct OpenEpoch {
    arrival: EpochArrival,
    spec: JobSpec,
    handle: JobHandle,
    open_secs: f64,
}

/// Builder for a continuous repartitioning job: an unbounded input
/// stream windowed into epochs, each shuffled through the batch
/// machinery, sealed in order, latency-tracked against an SLO. See the
/// [module docs](self) for the semantics.
pub struct StreamJob {
    source: IngestSource,
    workers: usize,
    epochs: usize,
    strategy: Arc<dyn ShuffleStrategy>,
    backend: Backend,
    /// `Some(seed)`: run on the deterministic simulation backend.
    sim_seed: Option<u64>,
    slo_secs: Option<f64>,
    chaos: Option<ChaosPlan>,
    /// Epoch the chaos plan arms on (default: mid-stream).
    chaos_epoch: Option<usize>,
    pipeline_depth: usize,
    verify_batch: bool,
    speculate: Option<f64>,
    name: String,
}

impl StreamJob {
    pub fn new(source: IngestSource, workers: usize) -> StreamJob {
        StreamJob {
            source,
            workers: workers.max(1),
            epochs: 4,
            strategy: Arc::new(TwoStageMerge),
            backend: Backend::Native,
            sim_seed: None,
            slo_secs: None,
            chaos: None,
            chaos_epoch: None,
            pipeline_depth: 2,
            verify_batch: false,
            speculate: None,
            name: "stream".to_string(),
        }
    }

    /// Epochs to run before stopping (a production stream would run
    /// forever; tests, benches and the CLI bound it).
    pub fn epochs(mut self, n: usize) -> StreamJob {
        self.epochs = n.max(1);
        self
    }

    pub fn strategy<S: ShuffleStrategy + 'static>(mut self, s: S) -> StreamJob {
        self.strategy = Arc::new(s);
        self
    }

    pub fn strategy_arc(mut self, s: Arc<dyn ShuffleStrategy>) -> StreamJob {
        self.strategy = s;
        self
    }

    pub fn backend(mut self, b: Backend) -> StreamJob {
        self.backend = b;
        self
    }

    /// Run on the deterministic simulation backend seeded with `seed`
    /// (virtual-time latencies, byte-identical replays — what vopr's
    /// `stream` workload sweeps).
    pub fn sim_seed(mut self, seed: u64) -> StreamJob {
        self.sim_seed = Some(seed);
        self
    }

    /// Arm a per-epoch ingest→sealed latency objective; epochs sealing
    /// above it count as SLO violations on the report.
    pub fn slo_ms(mut self, ms: f64) -> StreamJob {
        self.slo_secs = Some(ms / 1000.0);
        self
    }

    /// Arm a chaos plan against one mid-stream epoch (default: epoch
    /// `epochs / 2`). The plan's commit triggers are scoped to that
    /// epoch's own sort, and lineage recovery is likewise scoped — the
    /// stream must keep sealing byte-identical epochs through it.
    pub fn chaos(mut self, plan: ChaosPlan) -> StreamJob {
        self.chaos = Some(plan);
        self
    }

    /// Choose which epoch the chaos plan arms on.
    pub fn chaos_epoch(mut self, epoch: usize) -> StreamJob {
        self.chaos_epoch = Some(epoch);
        self
    }

    /// Epochs allowed open at once (default 2: epoch N+1's maps admit
    /// while epoch N's reduces drain). 1 degenerates to serial batch.
    pub fn pipeline_depth(mut self, depth: usize) -> StreamJob {
        self.pipeline_depth = depth.max(1);
        self
    }

    /// After the stream finishes, re-run every epoch as a one-shot
    /// batch sort of the same shards and record whether the bytes
    /// match ([`EpochReport::batch_identical`]).
    pub fn verify_batch(mut self, on: bool) -> StreamJob {
        self.verify_batch = on;
        self
    }

    /// Enable speculative re-execution of stragglers inside each epoch.
    pub fn speculate(mut self, multiplier: f64) -> StreamJob {
        self.speculate = Some(multiplier);
        self
    }

    pub fn name(mut self, name: impl Into<String>) -> StreamJob {
        self.name = name.into();
        self
    }

    /// The spec one epoch runs under: sized from the window's records,
    /// seeded from the source, carrying the source's key skew.
    fn epoch_spec(&self, arrival: &EpochArrival) -> JobSpec {
        let mut spec = JobSpec::scaled(arrival.records * RECORD_SIZE as u64, self.workers);
        spec.seed = arrival.seed;
        spec.skew = self.source.skew;
        spec.speculate = self.speculate;
        spec
    }

    /// Run the stream on a private service (sized for the epoch spec,
    /// backed by the configured backend), shut down on every path.
    pub fn run(self) -> anyhow::Result<StreamReport> {
        let spec0 = self.epoch_spec(&self.source.arrival(0));
        let mut cfg = ServiceConfig::for_spec(&spec0);
        cfg.sim_seed = self.sim_seed;
        let service = JobService::new(cfg);
        let result = self.run_on(&service);
        service.shutdown();
        result
    }

    /// Run the stream on a shared, long-lived service (the epochs
    /// contend with other tenants under fair-share scheduling). The
    /// service's backend is whatever it was built with; `sim_seed` only
    /// takes effect through [`StreamJob::run`].
    pub fn run_on(self, service: &JobService) -> anyhow::Result<StreamReport> {
        let rt = service.runtime();
        let clock = rt.clock();
        let t0 = clock.now_secs();
        let chaos_epoch = self
            .chaos_epoch
            .unwrap_or(self.epochs / 2)
            .min(self.epochs.saturating_sub(1));
        let mut tracker = LatencyTracker::new(self.slo_secs);
        let mut open: VecDeque<OpenEpoch> = VecDeque::new();
        let mut sealed: Vec<EpochReport> = Vec::new();
        let mut overlap_secs = 0.0;
        let mut max_open = 0usize;

        let seal_front = |open: &mut VecDeque<OpenEpoch>,
                              sealed: &mut Vec<EpochReport>,
                              tracker: &mut LatencyTracker,
                              overlap_secs: &mut f64|
         -> anyhow::Result<()> {
            let oe = open.pop_front().expect("seal with no open epoch");
            let mut report = oe.handle.wait().map_err(|e| {
                anyhow!("epoch {} failed: {e:#}", oe.arrival.epoch)
            })?;
            let sealed_secs = clock.now_secs() - t0;
            // ingest→sealed: the window's own fill time plus the
            // measured admit→seal processing time
            let latency_secs = oe.arrival.window_secs + (sealed_secs - oe.open_secs);
            let slo_violated = tracker.violates(latency_secs);
            tracker.record(latency_secs);
            report.latency = Some(tracker.stats());
            // the epoch retired when its driver finished (before wait()
            // returned): its store entries must already be swept
            let store_purged = rt.store_live_entries_for(oe.handle.id()) == 0;
            // an adjacent epoch already open at this seal is pipelining
            if let Some(next) = open.front() {
                *overlap_secs += (sealed_secs - next.open_secs).max(0.0);
            }
            sealed.push(EpochReport {
                epoch: oe.arrival.epoch,
                records: report.validation.summary.records,
                checksum: report.validation.summary.checksum,
                window_secs: oe.arrival.window_secs,
                open_secs: oe.open_secs,
                sealed_secs,
                latency_secs,
                slo_violated,
                store_purged,
                batch_identical: None,
                spec: oe.spec,
                report,
            });
            Ok(())
        };

        for e in 0..self.epochs {
            let arrival = self.source.arrival(e);
            let spec = self.epoch_spec(&arrival);
            let mut job = ShuffleJob::new(spec.clone())
                .strategy_arc(self.strategy.clone())
                .backend(self.backend.clone())
                .name(format!("{}-epoch-{e}", self.name));
            if e == chaos_epoch {
                if let Some(plan) = &self.chaos {
                    job = job.chaos(plan.clone());
                }
            }
            let open_secs = clock.now_secs() - t0;
            let handle = job.submit(service)?;
            open.push_back(OpenEpoch {
                arrival,
                spec,
                handle,
                open_secs,
            });
            max_open = max_open.max(open.len());
            while open.len() >= self.pipeline_depth {
                seal_front(
                    &mut open,
                    &mut sealed,
                    &mut tracker,
                    &mut overlap_secs,
                )?;
            }
        }
        while !open.is_empty() {
            seal_front(&mut open, &mut sealed, &mut tracker, &mut overlap_secs)?;
        }
        let total_secs = clock.now_secs() - t0;

        if self.verify_batch {
            for ep in &mut sealed {
                let r = self.batch_reference(ep)?;
                ep.batch_identical = Some(
                    r.validation.valid
                        && r.validation.summary.checksum == ep.checksum
                        && r.validation.summary.records == ep.records,
                );
            }
        }

        Ok(StreamReport {
            name: self.name,
            strategy: self.strategy.name().to_string(),
            watermark: sealed.len(),
            latency: tracker.stats(),
            pipeline_overlap_secs: overlap_secs,
            max_open_epochs: max_open,
            total_secs,
            total_records: sealed.iter().map(|e| e.records).sum(),
            total_bytes: sealed.iter().map(|e| e.spec.total_bytes).sum(),
            epochs: sealed,
        })
    }

    /// One-shot batch sort of an epoch's shards on a throwaway service
    /// (same backend family; a *different* sim seed on purpose — output
    /// bytes must not depend on event timing).
    fn batch_reference(&self, ep: &EpochReport) -> anyhow::Result<JobReport> {
        let mut cfg = ServiceConfig::for_spec(&ep.spec);
        cfg.sim_seed = self.sim_seed.map(|s| s ^ 0xBA7C);
        let service = JobService::new(cfg);
        let result = ShuffleJob::new(ep.spec.clone())
            .strategy_arc(self.strategy.clone())
            .backend(self.backend.clone())
            .name(format!("{}-batch-ref-{}", self.name, ep.epoch))
            .submit(&service)
            .and_then(|h| h.wait());
        service.shutdown();
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ingest_source_is_seed_deterministic() {
        let s = IngestSource::new(7, 1000.0, 500);
        let a0 = s.arrival(0);
        let a1 = s.arrival(1);
        assert_eq!(a0.records, 500);
        assert!((a0.window_secs - 0.5).abs() < 1e-12);
        assert_ne!(a0.seed, a1.seed, "epochs draw distinct shard seeds");
        assert_eq!(a0.seed, s.arrival(0).seed, "replays reproduce seeds");
    }

    #[test]
    fn bursts_shrink_the_window_not_the_records() {
        let mut s = IngestSource::new(7, 1000.0, 500);
        s.burst_every = 3;
        s.burst_factor = 4.0;
        let steady = s.arrival(0);
        let burst = s.arrival(2); // every 3rd epoch: indices 2, 5, 8…
        assert_eq!(steady.records, burst.records);
        assert!((burst.window_secs - steady.window_secs / 4.0).abs() < 1e-12);
        assert!((s.arrival(3).window_secs - steady.window_secs).abs() < 1e-12);
    }

    #[test]
    fn zero_rate_models_a_full_backlog() {
        let s = IngestSource::new(7, 0.0, 500);
        assert_eq!(s.arrival(0).window_secs, 0.0);
    }

    #[test]
    fn epoch_specs_differ_only_by_seed() {
        let source = IngestSource::new(11, 1000.0, 20_000);
        let job = StreamJob::new(source.clone(), 2);
        let s0 = job.epoch_spec(&source.arrival(0));
        let s1 = job.epoch_spec(&source.arrival(1));
        assert_ne!(s0.seed, s1.seed);
        assert_eq!(s0.total_bytes, s1.total_bytes);
        assert_eq!(s0.n_input_partitions, s1.n_input_partitions);
        assert_eq!(s0.n_output_partitions, s1.n_output_partitions);
        s0.check().expect("epoch specs validate");
    }
}
