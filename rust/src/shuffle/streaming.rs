//! Fully-pipelined streaming shuffle: the **entire** map → merge → reduce
//! DAG is submitted up front as chained distributed futures.
//!
//! This is the topology the event-driven runtime makes possible (the
//! Exoshuffle thesis in its purest form): merge batches are fixed ahead
//! of time — batch *b* on worker *w* merges block *w* of maps
//! `[b·T, (b+1)·T)` — so every merge can be submitted before any map has
//! produced a byte, with map output futures as its arguments; every
//! reduce is submitted with merge output futures as *its* arguments. No
//! `wait_quiescent`, no driver poll loop, no stage barrier: a reduce on
//! worker *w* starts the moment *w*'s last merge commits, while other
//! workers are still mapping or merging. Sequencing, locality and memory
//! backpressure all come from the runtime — readiness dispatch orders the
//! stages, and scheduler admission control (not a merge controller)
//! bounds residency.
//!
//! Compared to [`crate::shuffle::TwoStageMerge`]: same task bodies, same
//! merge fan-in cap, byte-identical output — but static batching instead
//! of arrival-order batching, and stage overlap instead of a driver
//! barrier between map_shuffle and reduce.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::Context;

use crate::coordinator::plan::JobSpec;
use crate::coordinator::tasks;
use crate::distfut::{future, ObjectRef, TaskHandle};
use crate::runtime::Backend;
use crate::shuffle::{ShuffleContext, ShuffleOutcome, ShuffleStrategy};

/// Whole-DAG-up-front topology (map → merge → reduce as chained futures).
pub struct StreamingShuffle;

impl ShuffleStrategy for StreamingShuffle {
    fn name(&self) -> &'static str {
        "streaming"
    }

    fn describe(&self) -> &'static str {
        "fully-pipelined map -> merge -> reduce: the whole DAG is \
         submitted up front as chained futures; stages overlap through \
         readiness scheduling (no driver barriers)"
    }

    fn stage_names(&self) -> &'static [&'static str] {
        // one fused stage: there are no driver-visible stage boundaries
        &["streaming"]
    }

    fn warmup(&self, spec: &JobSpec, backend: &Backend) -> anyhow::Result<()> {
        // same kernel shapes as the two-stage strategy (same task bodies)
        crate::shuffle::warmup_merge_topology(spec, backend)
    }

    fn run_stages(&self, cx: &ShuffleContext) -> anyhow::Result<ShuffleOutcome> {
        let spec = cx.spec;
        let w = spec.n_workers();
        let r1 = spec.reducers_per_worker();
        let m = spec.n_input_partitions;
        let threshold = spec.merge_threshold_blocks.max(1);
        let n_batches = spec.merge_batches_per_node();
        let worker_cuts = Arc::new(spec.worker_cuts());
        let mut clock = cx.stage_clock();

        // --- submit every map ---
        let mut map_blocks: Vec<Vec<ObjectRef>> = Vec::with_capacity(m);
        let mut map_handles: Vec<TaskHandle> = Vec::with_capacity(m);
        for p in 0..m {
            let (outs, h) = cx.submit(tasks::map_task(
                spec,
                cx.s3,
                cx.backend,
                worker_cuts.clone(),
                p,
            ));
            map_blocks.push(outs);
            map_handles.push(h);
        }

        // --- chain every merge against its map-block futures ---
        // Peak-unmerged gauge via readiness callbacks: +1 per block whose
        // data lands, −batch when the covering merge's outputs land (a
        // block always commits before its merge can run, so the gauge
        // never underflows). Note the semantics: this counts *resident*
        // unmerged blocks only. The two-stage controllers' backlog also
        // counts routed-but-unproduced blocks (their in-flight maps), so
        // when comparing peak_unmerged_blocks across strategies, this is
        // the memory-exposure lower bound, not an identical quantity —
        // counting routed blocks here would trivially read M, since the
        // whole DAG is routed up front.
        let gauges: Vec<Arc<AtomicUsize>> =
            (0..w).map(|_| Arc::new(AtomicUsize::new(0))).collect();
        let peak = Arc::new(AtomicUsize::new(0));
        let mut merged: Vec<Vec<Vec<ObjectRef>>> = Vec::with_capacity(w);
        let mut merge_handles: Vec<TaskHandle> =
            Vec::with_capacity(w * n_batches);
        for node in 0..w {
            let mut batches: Vec<Vec<ObjectRef>> = Vec::with_capacity(n_batches);
            for b in 0..n_batches {
                let lo = b * threshold;
                let hi = ((b + 1) * threshold).min(m);
                let blocks: Vec<ObjectRef> = map_blocks[lo..hi]
                    .iter()
                    .map(|outs| outs[node].clone())
                    .collect();
                for block in &blocks {
                    let g = gauges[node].clone();
                    let pk = peak.clone();
                    cx.rt.on_ready(block, move || {
                        let v = g.fetch_add(1, Ordering::Relaxed) + 1;
                        pk.fetch_max(v, Ordering::Relaxed);
                    });
                }
                let batch_len = blocks.len();
                let (outs, h) = cx.submit(tasks::merge_task(
                    spec, cx.backend, node, b, blocks,
                ));
                let g = gauges[node].clone();
                cx.rt.on_ready(&outs[0], move || {
                    g.fetch_sub(batch_len, Ordering::Relaxed);
                });
                batches.push(outs);
                merge_handles.push(h);
            }
            merged.push(batches);
        }
        drop(map_blocks); // merge specs hold the only remaining block refs

        // --- chain every reduce against its merge-output futures ---
        let mut reduce_handles: Vec<TaskHandle> =
            Vec::with_capacity(spec.n_output_partitions);
        for (node, batches) in merged.iter().enumerate() {
            for j in 0..r1 {
                let global_r = node * r1 + j;
                let blocks: Vec<ObjectRef> =
                    batches.iter().map(|batch| batch[j].clone()).collect();
                let (_outs, h) = cx.submit(tasks::reduce_task(
                    spec, cx.s3, cx.backend, node, global_r, blocks,
                ));
                reduce_handles.push(h);
            }
        }
        drop(merged); // reduce specs hold the only remaining merged refs

        // the only join in the strategy: the DAG's sinks. On failure,
        // probe upstream handles so the error names the root cause
        // instead of a cascaded "object released".
        if let Err(sink_err) = future::wait_all(&reduce_handles) {
            future::wait_all(&map_handles).context("streaming shuffle (map)")?;
            future::wait_all(&merge_handles)
                .context("streaming shuffle (merge)")?;
            return Err(sink_err).context("streaming shuffle (reduce)");
        }
        clock.lap("streaming");

        Ok(ShuffleOutcome {
            stages: clock.into_stages(),
            n_map_tasks: m,
            n_merge_tasks: w * n_batches,
            n_reduce_tasks: reduce_handles.len(),
            peak_unmerged_blocks: peak.load(Ordering::Relaxed),
        })
    }
}
