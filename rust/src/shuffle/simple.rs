//! Simple shuffle: the Exoshuffle paper's baseline topology — map tasks
//! partition directly into R output ranges, and each reduce task merges
//! its block from *every* map. No merge stage, no backpressure.
//!
//! This is the textbook MapReduce shuffle. It is correct at any scale but
//! its reduce fan-in is M (50 000 at CloudSort scale, versus
//! merges-per-node ≈ 32 under [`crate::shuffle::TwoStageMerge`]), and
//! every map×reduce block stays resident until the reduce stage drains it
//! — which is exactly the scaling wall the paper's pre-shuffle merge
//! removes. Useful as a correctness cross-check and as the ablation
//! baseline for the strategy API.

use std::sync::Arc;

use anyhow::Context;

use crate::coordinator::plan::JobSpec;
use crate::coordinator::tasks;
use crate::distfut::{future, ObjectRef, TaskHandle};
use crate::runtime::Backend;
use crate::shuffle::{ShuffleContext, ShuffleOutcome, ShuffleStrategy};

/// Single-pass map → reduce topology (no merge stage).
pub struct SimpleShuffle;

impl ShuffleStrategy for SimpleShuffle {
    fn name(&self) -> &'static str {
        "simple"
    }

    fn describe(&self) -> &'static str {
        "single-pass map -> reduce with R-way map partitioning and M-way \
         reduce fan-in (Exoshuffle baseline)"
    }

    fn stage_names(&self) -> &'static [&'static str] {
        &["map", "reduce"]
    }

    fn warmup(&self, spec: &JobSpec, backend: &Backend) -> anyhow::Result<()> {
        let rpp = spec.records_per_partition() as usize;
        // reduce merges M runs of ~records-per-(map × reducer) each
        let run = (rpp / spec.n_output_partitions.max(1)).max(2);
        crate::runtime::warmup(backend, rpp, spec.n_input_partitions, run)
    }

    fn run_stages(&self, cx: &ShuffleContext) -> anyhow::Result<ShuffleOutcome> {
        let spec = cx.spec;
        let r = spec.n_output_partitions;
        let r1 = spec.reducers_per_worker();
        let reducer_cuts = Arc::new(spec.reducer_cuts());
        let mut clock = cx.stage_clock();

        // --- stage 1: map. Each map sorts its partition and splits it
        // R ways; admission is slot-bounded so the driver queue (not the
        // runtime queue) is where tasks wait. ---
        let mut map_outs: Vec<Vec<ObjectRef>> =
            Vec::with_capacity(spec.n_input_partitions);
        let mut map_handles: Vec<TaskHandle> =
            Vec::with_capacity(spec.n_input_partitions);
        let mut next_map = 0usize;
        while next_map < spec.n_input_partitions {
            if future::pending_count(&map_handles)
                >= spec.cluster.total_slots() * 2
            {
                // park (not sleep): under the sim backend this pumps the
                // event loop instead of stalling virtual time
                cx.rt.park(std::time::Duration::from_micros(500));
                continue;
            }
            let (outs, h) = rt_submit_map(cx, reducer_cuts.clone(), next_map);
            map_outs.push(outs);
            map_handles.push(h);
            next_map += 1;
        }
        future::wait_all(&map_handles).context("map stage")?;
        clock.lap("map");

        // --- stage 2: reduce. Reducer r merges the r-th block of every
        // map; pinned to the worker that owns the reducer range so output
        // placement matches the two-stage strategy. ---
        let mut handles = Vec::with_capacity(r);
        for global_r in 0..r {
            let node = global_r / r1;
            let blocks: Vec<ObjectRef> =
                map_outs.iter().map(|outs| outs[global_r].clone()).collect();
            let (_outs, h) = cx.submit(tasks::reduce_task(
                spec, cx.s3, cx.backend, node, global_r, blocks,
            ));
            handles.push(h);
        }
        drop(map_outs); // reduces hold the only remaining block refs
        future::wait_all(&handles).context("reduce stage")?;
        clock.lap("reduce");

        Ok(ShuffleOutcome {
            stages: clock.into_stages(),
            n_map_tasks: spec.n_input_partitions,
            n_merge_tasks: 0,
            n_reduce_tasks: handles.len(),
            // without a merge stage every map's blocks stay resident
            // until reduce: per-worker exposure is the full map count
            // (in map-slice units) — nothing bounds it (ablation A1).
            peak_unmerged_blocks: spec.n_input_partitions,
        })
    }
}

fn rt_submit_map(
    cx: &ShuffleContext,
    cuts: Arc<Vec<u64>>,
    p: usize,
) -> (Vec<ObjectRef>, TaskHandle) {
    cx.submit(tasks::map_task(cx.spec, cx.s3, cx.backend, cuts, p))
}
