//! The paper's two-stage shuffle (§2.3–2.4): map tasks sort and split
//! input partitions across worker ranges; per-worker merge controllers
//! batch incoming blocks and launch pre-shuffle merge tasks under
//! backpressure; a barrier; then one reduce task per output partition
//! merges that reducer's block from every merge batch.
//!
//! This is the Exoshuffle-CloudSort design: merging ahead of the reduce
//! stage caps the reduce fan-in at merges-per-node (instead of M), which
//! is what makes the 100 TB / 50 000-partition run tractable.

use std::sync::Arc;

use anyhow::Context;

use crate::coordinator::merge_controller::MergeController;
use crate::coordinator::plan::JobSpec;
use crate::coordinator::tasks;
use crate::distfut::{future, TaskHandle};
use crate::runtime::Backend;
use crate::shuffle::{ShuffleContext, ShuffleOutcome, ShuffleStrategy};

/// Driver-side admission poll interval: how often the map-submission
/// loop re-checks the backpressure predicate. Only map *admission* polls
/// (as the paper's driver does); block promotion and merge launching are
/// event-driven inside [`MergeController`].
const ADMISSION_POLL: std::time::Duration = std::time::Duration::from_micros(500);

/// The paper's pre-shuffle-merge topology (default strategy).
pub struct TwoStageMerge;

impl ShuffleStrategy for TwoStageMerge {
    fn name(&self) -> &'static str {
        "two-stage-merge"
    }

    fn describe(&self) -> &'static str {
        "map & shuffle with per-worker merge backpressure, then reduce \
         (Exoshuffle-CloudSort §2.3)"
    }

    fn stage_names(&self) -> &'static [&'static str] {
        &["map_shuffle", "reduce"]
    }

    fn warmup(&self, spec: &JobSpec, backend: &Backend) -> anyhow::Result<()> {
        crate::shuffle::warmup_merge_topology(spec, backend)
    }

    fn run_stages(&self, cx: &ShuffleContext) -> anyhow::Result<ShuffleOutcome> {
        let spec = cx.spec;
        let mut clock = cx.stage_clock();

        // --- stage 1: map & shuffle (§2.3) ---
        let controllers = map_shuffle_stage(cx)?;
        clock.lap("map_shuffle");
        let n_merge_tasks: usize =
            controllers.iter().map(|c| c.merges_launched()).sum();
        let peak_unmerged_blocks = controllers
            .iter()
            .map(|c| c.peak_backlog())
            .max()
            .unwrap_or(0);

        // --- stage 2: reduce (§2.4) ---
        let n_reduce_tasks = reduce_stage(cx, controllers)?;
        clock.lap("reduce");

        Ok(ShuffleOutcome {
            stages: clock.into_stages(),
            n_map_tasks: spec.n_input_partitions,
            n_merge_tasks,
            n_reduce_tasks,
            peak_unmerged_blocks,
        })
    }
}

/// Stage 1: the map & shuffle loop. Submits map tasks respecting merge
/// backpressure and routes map output futures to per-worker merge
/// controllers, whose readiness callbacks buffer blocks and launch
/// merges as the data lands — the driver only throttles map admission.
/// Returns the controllers once every map and merge has completed.
fn map_shuffle_stage(
    cx: &ShuffleContext,
) -> anyhow::Result<Vec<MergeController>> {
    let (spec, s3, backend) = (cx.spec, cx.s3, cx.backend);
    let w = spec.n_workers();
    let worker_cuts = Arc::new(spec.worker_cuts());
    let backend2 = backend.clone();
    let spec2 = spec.clone();
    let controllers: Vec<MergeController> = (0..w)
        .map(|node| {
            let backend = backend2.clone();
            let spec = spec2.clone();
            MergeController::for_job(
                node,
                spec2.merge_threshold_blocks,
                cx.rt,
                cx.job,
                Arc::new(move |node, batch, blocks| {
                    tasks::merge_task(&spec, &backend, node, batch, blocks)
                }),
            )
        })
        .collect();

    let mut map_handles: Vec<TaskHandle> =
        Vec::with_capacity(spec.n_input_partitions);
    let backlog_limit = spec.max_buffered_blocks.max(1);
    let merge_parallelism = spec.cluster.task_parallelism().max(1);
    let mut next_map = 0usize;
    while next_map < spec.n_input_partitions {
        // submit maps while backpressure allows (paper: the driver queues
        // extra tasks and feeds nodes as they free up; the runtime's
        // shared queue does the feeding, this loop does admission control)
        let blocked = spec.backpressure
            && controllers
                .iter()
                .any(|c| c.saturated(merge_parallelism, backlog_limit));
        // admission is also bounded by total slots to keep the driver
        // queue (not the runtime queue) the place where tasks wait
        let in_flight = future::pending_count(&map_handles);
        if blocked || in_flight >= spec.cluster.total_slots() * 2 {
            // park (not sleep): under the sim backend this pumps the
            // event loop instead of stalling virtual time
            cx.rt.park(ADMISSION_POLL);
            continue;
        }
        let (outs, h) = cx.submit(tasks::map_task(
            spec,
            s3,
            backend,
            worker_cuts.clone(),
            next_map,
        ));
        for (node, block) in outs.into_iter().enumerate() {
            controllers[node].on_map_block(block);
        }
        map_handles.push(h);
        next_map += 1;
    }
    future::wait_all(&map_handles).context("map stage")?;
    // tail merges + barrier: "once all map and merge tasks finish" (§2.3)
    for c in &controllers {
        c.flush();
    }
    for c in &controllers {
        c.wait_all().context("merge stage")?;
    }
    Ok(controllers)
}

/// Stage 2: reduce. One task per output partition, pinned to the worker
/// that owns the reducer range; merges that reducer's block from every
/// merge batch and uploads the output partition.
fn reduce_stage(
    cx: &ShuffleContext,
    controllers: Vec<MergeController>,
) -> anyhow::Result<usize> {
    let spec = cx.spec;
    let r1 = spec.reducers_per_worker();
    let mut handles = Vec::with_capacity(spec.n_output_partitions);
    for c in &controllers {
        let merged = c.merged_outputs();
        for j in 0..r1 {
            let global_r = c.node * r1 + j;
            let blocks: Vec<_> =
                merged.iter().map(|batch| batch[j].clone()).collect();
            let (_outs, h) = cx.submit(tasks::reduce_task(
                spec, cx.s3, cx.backend, c.node, global_r, blocks,
            ));
            handles.push(h);
        }
    }
    drop(controllers); // release merged-block refs held by controllers
    future::wait_all(&handles).context("reduce stage")?;
    Ok(handles.len())
}
