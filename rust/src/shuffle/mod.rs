//! Shuffle-as-a-library: the composable public API of this crate.
//!
//! The paper's central claim is that shuffle is an *application-level
//! library* over distributed futures, not a hard-wired pipeline. This
//! module is that library surface: a [`ShuffleJob`] builder configures a
//! job (spec, compute backend, object store) and a [`ShuffleStrategy`]
//! owns the *stage topology* — which tasks run, in what stages, under
//! which backpressure policy. The CloudSort reproduction is just one
//! strategy ([`TwoStageMerge`], the paper's §2.3 pre-shuffle-merge
//! design); the Exoshuffle baseline ([`SimpleShuffle`], straight
//! map → reduce) is another; [`StreamingShuffle`] submits the whole
//! map → merge → reduce DAG up front as chained futures, with zero
//! driver-side barriers — pipelining falls out of the event-driven
//! runtime, not the strategy.
//!
//! ```no_run
//! use exoshuffle::prelude::*;
//! # fn main() -> anyhow::Result<()> {
//! let report = ShuffleJob::new(JobSpec::scaled(64 << 20, 4))
//!     .strategy(SimpleShuffle)
//!     .backend(Backend::Native)
//!     .run()?;
//! assert!(report.validation.valid);
//! # Ok(()) }
//! ```
//!
//! Everything outside the timed shuffle — input generation, valsort-style
//! validation, report assembly — is owned by [`ShuffleJob::run`] so every
//! strategy is measured and checked identically (§3.2).

pub mod report;
pub mod simple;
pub mod streaming;
pub mod streaming_service;
pub mod two_stage;

use std::sync::Arc;

use anyhow::anyhow;

pub use report::{JobReport, StageTiming, ValidationReport};
pub use simple::SimpleShuffle;
pub use streaming::StreamingShuffle;
pub use streaming_service::{
    EpochReport, IngestSource, StreamJob, StreamReport,
};
pub use two_stage::TwoStageMerge;

use crate::coordinator::plan::JobSpec;
use crate::coordinator::{generate, validate};
use crate::distfut::chaos::{ChaosHarness, ChaosPlan};
use crate::distfut::{
    Clock, JobId, JobParams, ObjectRef, RuntimeHandle, TaskHandle, TaskSpec,
};
use crate::runtime::Backend;
use crate::s3sim::S3;
use crate::service::{JobHandle, JobService, ServiceConfig};

/// Everything a strategy needs to drive its stages: the job plan, the
/// object store standing in for S3, the compute backend, the
/// distributed-futures runtime it submits tasks to, and the job identity
/// the runtime accounts those tasks under. Strategies own the control
/// plane; `cx.rt` is the data plane (§2.1). The runtime is a cloneable
/// [`RuntimeHandle`] — threaded or simulated — so strategies can park
/// readiness callbacks (e.g. merge controllers) that outlive the current
/// stack frame, and run unchanged on either backend.
pub struct ShuffleContext<'a> {
    pub spec: &'a JobSpec,
    pub s3: &'a S3,
    pub backend: &'a Backend,
    pub rt: &'a RuntimeHandle,
    /// The job every task of this run is tagged with — the runtime may
    /// be shared with other concurrent jobs ([`crate::service`]).
    pub job: JobId,
}

impl ShuffleContext<'_> {
    /// Submit a task on behalf of this context's job (strategies route
    /// every submission through here so a shared runtime can account,
    /// fair-share and tear down per job).
    pub fn submit(&self, spec: TaskSpec) -> (Vec<ObjectRef>, TaskHandle) {
        self.rt.submit_for(self.job, spec)
    }

    /// Stage stopwatch on this runtime's clock: wall time under the
    /// threaded backend, virtual time under [`crate::distfut::sim`].
    /// Strategies must time stages through this (not `Instant`) so
    /// simulated runs report deterministic timings.
    pub fn stage_clock(&self) -> StageClock {
        StageClock::start_at(self.rt.clock())
    }
}

/// What a strategy hands back after its timed stages complete.
pub struct ShuffleOutcome {
    /// Per-stage wall times, in execution order, keyed by the names the
    /// strategy declared in [`ShuffleStrategy::stage_names`].
    pub stages: Vec<StageTiming>,
    /// Tasks launched by the control plane, per family.
    pub n_map_tasks: usize,
    pub n_merge_tasks: usize,
    pub n_reduce_tasks: usize,
    /// Peak per-worker count of shuffled-but-unconsumed map blocks — the
    /// memory exposure §2.3 backpressure bounds (ablation A1).
    pub peak_unmerged_blocks: usize,
}

/// A shuffle stage topology. Implementations submit tasks against
/// `cx.rt`, decide stage boundaries, and report per-stage timings; the
/// surrounding generate/validate loops and the report are shared
/// ([`ShuffleJob::run`]).
pub trait ShuffleStrategy: Send + Sync {
    /// Registry name (also what `--strategy` matches).
    fn name(&self) -> &'static str;

    /// One-line description for `--list-strategies`.
    fn describe(&self) -> &'static str;

    /// The ordered stage names this strategy will report timings for.
    /// [`ShuffleOutcome::stages`] must use exactly these names.
    fn stage_names(&self) -> &'static [&'static str];

    /// Pre-compile the kernel shapes this strategy will execute (one-time
    /// XLA compilation is startup cost, not sort time).
    fn warmup(&self, spec: &JobSpec, backend: &Backend) -> anyhow::Result<()>;

    /// Execute the timed shuffle stages.
    fn run_stages(&self, cx: &ShuffleContext) -> anyhow::Result<ShuffleOutcome>;
}

/// Stage stopwatch shared by strategies: `lap(name)` closes the current
/// stage and starts the next one. Reads whichever [`Clock`] it was
/// started on, so the same strategy code reports wall-clock stage times
/// on the threaded runtime and virtual-time stage times under the
/// deterministic simulator.
pub struct StageClock {
    clock: Clock,
    t0: f64,
    stages: Vec<StageTiming>,
}

impl StageClock {
    /// Start the stopwatch on an explicit clock (what
    /// [`ShuffleContext::stage_clock`] does with the runtime's clock).
    pub fn start_at(clock: Clock) -> StageClock {
        let t0 = clock.now_secs();
        StageClock {
            clock,
            t0,
            stages: Vec::new(),
        }
    }

    /// Start on the wall clock (standalone uses and tests).
    pub fn start() -> StageClock {
        StageClock::start_at(Clock::wall())
    }

    /// Close the current stage under `name`.
    pub fn lap(&mut self, name: &str) {
        let now = self.clock.now_secs();
        self.stages.push(StageTiming {
            name: name.to_string(),
            secs: now - self.t0,
        });
        self.t0 = now;
    }

    pub fn into_stages(self) -> Vec<StageTiming> {
        self.stages
    }
}

/// Pre-compile the kernel shapes of the merge-based topologies (map
/// sort+partition at worker granularity, threshold-wide merges, and the
/// merged-batch reduce). Shared by [`TwoStageMerge`] and
/// [`StreamingShuffle`], which run the same task bodies.
pub(crate) fn warmup_merge_topology(
    spec: &JobSpec,
    backend: &Backend,
) -> anyhow::Result<()> {
    let rpp = spec.records_per_partition() as usize;
    let slice = rpp / spec.n_workers().max(1);
    let merges_per_node = spec.merge_batches_per_node();
    let reduce_run = (spec.total_records() as usize
        / spec.n_output_partitions.max(1))
        / merges_per_node.max(1);
    crate::runtime::warmup(
        backend,
        rpp,
        spec.merge_threshold_blocks.min(spec.n_input_partitions),
        slice.max(2),
    )?;
    crate::runtime::warmup(backend, 2, merges_per_node, reduce_run.max(2))
}

/// Look up a strategy by registry name (accepts the aliases the CLI
/// documents). `None` for unknown names.
pub fn strategy_by_name(name: &str) -> Option<Arc<dyn ShuffleStrategy>> {
    match name {
        "two-stage-merge" | "two-stage" | "cloudsort" => {
            Some(Arc::new(TwoStageMerge))
        }
        "simple" | "simple-shuffle" => Some(Arc::new(SimpleShuffle)),
        "streaming" | "streaming-shuffle" => Some(Arc::new(StreamingShuffle)),
        _ => None,
    }
}

/// All registered strategies, for `--list-strategies` and tests.
pub fn list_strategies() -> Vec<Arc<dyn ShuffleStrategy>> {
    vec![
        Arc::new(TwoStageMerge),
        Arc::new(SimpleShuffle),
        Arc::new(StreamingShuffle),
    ]
}

/// Builder for a full shuffle run: generate → shuffle (strategy-owned
/// stages) → validate. Defaults reproduce the paper's CloudSort job:
/// [`TwoStageMerge`] on the native backend against a fresh S3 stand-in.
///
/// Two execution paths share one pipeline:
/// - [`ShuffleJob::run`] — one-shot: spins up a throwaway
///   [`JobService`] (and thus a private runtime), runs the job, and
///   shuts the service down on *every* path, success or error — worker
///   threads can no longer leak when a stage fails.
/// - [`ShuffleJob::submit`] — multi-tenant: hands the job to a shared
///   long-lived [`JobService`] and returns a non-blocking
///   [`JobHandle`]; many jobs run concurrently under fair-share
///   scheduling with per-job isolation.
pub struct ShuffleJob {
    pub(crate) spec: JobSpec,
    pub(crate) strategy: Arc<dyn ShuffleStrategy>,
    pub(crate) backend: Backend,
    pub(crate) s3: Option<S3>,
    pub(crate) chaos: Option<ChaosPlan>,
    pub(crate) name: Option<String>,
    pub(crate) params: JobParams,
}

impl ShuffleJob {
    pub fn new(spec: JobSpec) -> ShuffleJob {
        ShuffleJob {
            spec,
            strategy: Arc::new(TwoStageMerge),
            backend: Backend::Native,
            s3: None,
            chaos: None,
            name: None,
            params: JobParams::default(),
        }
    }

    /// Human-readable job name (reports, `serve` output). Defaults to
    /// the runtime-assigned `job-N`.
    pub fn name(mut self, name: impl Into<String>) -> ShuffleJob {
        self.name = Some(name.into());
        self
    }

    /// Fair-share weight (priority) inside a shared [`JobService`]: a
    /// weight-2.0 job receives twice the task slots of a weight-1.0 one
    /// while both are runnable. Default 1.0.
    pub fn priority(mut self, weight: f64) -> ShuffleJob {
        self.params.weight = weight;
        self
    }

    /// Quota: hard cap on this job's concurrently executing tasks.
    pub fn max_in_flight(mut self, tasks: usize) -> ShuffleJob {
        self.params.max_in_flight = Some(tasks);
        self
    }

    /// Quota: resident-byte budget — while the job's store residency
    /// exceeds it, its load-balanced tasks are not dispatched (pinned
    /// consumers still drain it).
    pub fn resident_budget(mut self, bytes: u64) -> ShuffleJob {
        self.params.resident_budget = Some(bytes);
        self
    }

    /// Select the stage topology (default: [`TwoStageMerge`]).
    pub fn strategy<S: ShuffleStrategy + 'static>(mut self, s: S) -> ShuffleJob {
        self.strategy = Arc::new(s);
        self
    }

    /// Select the stage topology from a registry handle (what the CLI's
    /// `--strategy` resolves through [`strategy_by_name`]).
    pub fn strategy_arc(mut self, s: Arc<dyn ShuffleStrategy>) -> ShuffleJob {
        self.strategy = s;
        self
    }

    /// Select the compute backend (default: [`Backend::Native`]).
    pub fn backend(mut self, b: Backend) -> ShuffleJob {
        self.backend = b;
        self
    }

    /// Run against a caller-provided S3 (lets tests inject faults or
    /// pre-populate inputs). Default: a fresh store with
    /// `spec.s3_buckets` buckets.
    pub fn on(mut self, s3: &S3) -> ShuffleJob {
        self.s3 = Some(s3.clone());
        self
    }

    /// Arm a deterministic failure schedule over the timed sort (§2.5
    /// resilience): the plan's commit-count triggers start counting after
    /// input generation, so injection points land inside the shuffle
    /// itself. The fired events and recovery counters come back on
    /// [`JobReport::chaos`] / [`JobReport::recovery`].
    pub fn chaos(mut self, plan: ChaosPlan) -> ShuffleJob {
        self.chaos = Some(plan);
        self
    }

    /// Run the full pipeline: generate → warmup → timed shuffle stages →
    /// validate. The returned report carries Table 1 and Table 2 inputs.
    ///
    /// Thin wrapper over the multi-tenant path: a throwaway
    /// [`JobService`] (sized from the spec) runs this single job and is
    /// shut down afterwards — on the error path too, so a failing stage
    /// no longer leaks the runtime's worker threads.
    pub fn run(self) -> anyhow::Result<JobReport> {
        let service = JobService::new(ServiceConfig::for_spec(&self.spec));
        let result = service.submit(self).and_then(|h| h.wait());
        service.shutdown();
        result
    }

    /// Submit this job to a shared, long-lived [`JobService`] and return
    /// a non-blocking [`JobHandle`]. Many jobs run concurrently on the
    /// service's runtime under weighted fair-share scheduling; quotas
    /// set via [`ShuffleJob::max_in_flight`] /
    /// [`ShuffleJob::resident_budget`] apply per job.
    pub fn submit(self, service: &JobService) -> anyhow::Result<JobHandle> {
        service.submit(self)
    }
}

/// Execute one job's full pipeline (generate → warmup → timed stages →
/// validate) against a shared runtime, with every task accounted to
/// `id`. Shared by the one-shot [`ShuffleJob::run`] wrapper and the
/// multi-tenant [`JobService`] worker threads; the caller owns job
/// teardown ([`RuntimeHandle::retire_job`]) and fills
/// [`JobReport::events`] from it. Spec validation (consistency + worker
/// count vs runtime nodes) happens once, at [`JobService::submit`] — the
/// single entry point both paths funnel through.
pub(crate) fn execute_on(
    mut job: ShuffleJob,
    rt: &RuntimeHandle,
    id: JobId,
) -> anyhow::Result<JobReport> {
    let name = job
        .name
        .clone()
        .unwrap_or_else(|| id.to_string());
    let s3 = match &job.s3 {
        Some(s3) => s3.clone(),
        None => S3::with_buckets(job.spec.s3_buckets),
    };

    // --- input generation (§3.2), not part of the timed sort ---
    let clock = rt.clock();
    let t0 = clock.now_secs();
    let (input_records, input_checksum) =
        generate::generate_input(&job.spec, &s3, rt, id)?;
    let gen_secs = clock.now_secs() - t0;

    // --- key sampling (adaptive range partitioning), untimed like
    // generation: choose reducer cuts from the sampled key CDF and
    // install them on the spec before the strategies read their cuts.
    // A spec that already carries sampled cuts is left alone.
    let mut sample_secs = 0.0;
    let mut sampled_keys = 0usize;
    if job.spec.sample_fraction > 0.0
        && job.spec.cuts == crate::coordinator::plan::Cuts::Uniform
    {
        let t0 = clock.now_secs();
        let (cuts, n_keys) = generate::sample_cuts(&job.spec, &s3, rt, id)?;
        job.spec.cuts =
            crate::coordinator::plan::Cuts::Sampled(Arc::new(cuts));
        sampled_keys = n_keys;
        sample_secs = clock.now_secs() - t0;
    }
    let spec = &job.spec;
    s3.reset_counters(); // Table 2 counts requests of the sort itself

    job.strategy.warmup(spec, &job.backend)?;

    // Chaos (if any) arms against the post-generation commit clock of
    // *this job only*: trigger thresholds are relative to the job's own
    // sort — neither the prelude nor other tenants' commits shift them.
    let harness = job
        .chaos
        .as_ref()
        .map(|plan| ChaosHarness::arm_for_job(rt, plan.clone(), id));

    // --- the timed shuffle: stage topology owned by the strategy ---
    let cx = ShuffleContext {
        spec,
        s3: &s3,
        backend: &job.backend,
        rt,
        job: id,
    };
    let outcome = job.strategy.run_stages(&cx);
    // the failure window is the timed sort: stop observing commits now
    // (error path included), so an unexhausted plan neither counts
    // validation traffic nor lingers on a shared runtime after this job
    // retires
    if let Some(h) = &harness {
        h.disarm();
    }
    let outcome = outcome?;
    // enforce the trait contract in every build: reported stage names
    // must match the declaration exactly, in order — JobReport's
    // Table 1 accessors key on them
    let reported: Vec<&str> =
        outcome.stages.iter().map(|s| s.name.as_str()).collect();
    if reported != job.strategy.stage_names() {
        return Err(anyhow!(
            "strategy '{}' reported stages {:?} but declared {:?}",
            job.strategy.name(),
            reported,
            job.strategy.stage_names()
        ));
    }
    let total_secs = outcome.stages.iter().map(|s| s.secs).sum();
    let s3_counters = s3.counters();

    // --- validation (§3.2), untimed ---
    let validation = validate::validate_output(
        spec,
        &s3,
        rt,
        id,
        input_records,
        input_checksum,
    )?;

    Ok(JobReport {
        name,
        job: id,
        strategy: job.strategy.name().to_string(),
        gen_secs,
        sample_secs,
        sampled_keys,
        stages: outcome.stages,
        total_secs,
        validation,
        s3: s3_counters,
        store: rt.store_stats(),
        // filled by the caller from `Runtime::retire_job` (the events
        // drained there are exactly this job's)
        events: Vec::new(),
        task_counts: rt.task_counts(),
        n_map_tasks: outcome.n_map_tasks,
        n_merge_tasks: outcome.n_merge_tasks,
        n_reduce_tasks: outcome.n_reduce_tasks,
        peak_unmerged_blocks: outcome.peak_unmerged_blocks,
        node_timeline: rt.node_count_timeline(),
        recovery: rt.recovery_stats(),
        speculation: rt.speculation_stats(),
        chaos: harness.map(|h| h.log()).unwrap_or_default(),
        latency: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_names_and_aliases() {
        for name in ["two-stage-merge", "two-stage", "cloudsort"] {
            assert_eq!(
                strategy_by_name(name).unwrap().name(),
                "two-stage-merge"
            );
        }
        for name in ["simple", "simple-shuffle"] {
            assert_eq!(strategy_by_name(name).unwrap().name(), "simple");
        }
        for name in ["streaming", "streaming-shuffle"] {
            assert_eq!(strategy_by_name(name).unwrap().name(), "streaming");
        }
        assert!(strategy_by_name("push-based").is_none());
    }

    #[test]
    fn registry_lists_every_strategy_with_stages() {
        let all = list_strategies();
        assert!(all.len() >= 2);
        for s in &all {
            assert!(!s.stage_names().is_empty(), "{} declares no stages", s.name());
            assert!(!s.describe().is_empty());
            // names round-trip through the registry
            assert_eq!(strategy_by_name(s.name()).unwrap().name(), s.name());
        }
    }

    #[test]
    fn stage_clock_orders_laps() {
        let mut c = StageClock::start();
        c.lap("a");
        c.lap("b");
        let stages = c.into_stages();
        assert_eq!(stages.len(), 2);
        assert_eq!(stages[0].name, "a");
        assert_eq!(stages[1].name, "b");
        assert!(stages.iter().all(|s| s.secs >= 0.0));
    }
}
