//! `exoshuffle` — launcher CLI for the Exoshuffle-CloudSort reproduction.
//!
//! Subcommands:
//!   sort      run a scaled shuffle job end-to-end (generate → sort → validate)
//!   sim       discrete-event simulation of the full 100 TB benchmark
//!   vopr      seed-sweep fuzzer over the deterministic simulation runtime
//!   cost      print the Table 2 cost breakdown for a run profile
//!   info      print artifact/backend information
//!
//! The offline environment has no clap; argument parsing is a small
//! hand-rolled layer (`--key value` flags after the subcommand, with
//! bare `--flag` treated as `--flag true`).

use std::collections::{HashMap, HashSet};
use std::io::Write;
use std::path::PathBuf;

use exoshuffle::config::{parse_bytes, Config};
use exoshuffle::coordinator::JobSpec;
use exoshuffle::cost::{CostModel, RunProfile};
use exoshuffle::distfut::chaos::{ChaosEvent, ChaosPlan};
use exoshuffle::runtime::Backend;
use exoshuffle::service::{
    Autoscaler, AutoscalerConfig, JobService, ServiceConfig,
};
use exoshuffle::shuffle::{
    list_strategies, strategy_by_name, IngestSource, ShuffleJob, StreamJob,
};
use exoshuffle::sim::{
    estimate_autoscale, estimate_multi_job, estimate_stream, simulate,
    SimConfig, SimStrategy,
};
use exoshuffle::sortlib::Skew;
use exoshuffle::util::rng::stream_at;
use exoshuffle::util::{human_bytes, human_secs};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

/// Flags that stand alone (bare `--flag` means `--flag true`); all other
/// flags require a value.
const BOOLEAN_FLAGS: &[&str] = &[
    "no-backpressure",
    "list-strategies",
    "events",
    "autoscale",
    "resume",
    "speculate",
    "stream",
    "verify-batch",
];

/// Parse `--key value` pairs after the subcommand. A flag listed in
/// [`BOOLEAN_FLAGS`] may appear bare; a value flag with a missing value
/// is an error (not a silent "true").
fn parse_flags(args: &[String]) -> Result<HashMap<String, String>, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let k = args[i]
            .strip_prefix("--")
            .ok_or_else(|| format!("expected --flag, got '{}'", args[i]))?;
        let boolean = BOOLEAN_FLAGS.contains(&k);
        match args.get(i + 1) {
            Some(v) if !v.starts_with("--") => {
                flags.insert(k.to_string(), v.clone());
                i += 2;
            }
            _ if boolean => {
                flags.insert(k.to_string(), "true".to_string());
                i += 1;
            }
            _ => return Err(format!("--{k} needs a value")),
        }
    }
    Ok(flags)
}

/// Default `--backend`: the XLA engine when this build carries it, the
/// self-contained native path otherwise — so the no-flags happy path
/// always runs.
const DEFAULT_BACKEND: &str = if cfg!(feature = "pjrt") { "xla" } else { "native" };

fn run(args: Vec<String>) -> anyhow::Result<()> {
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = parse_flags(&args[args.len().min(1)..])
        .map_err(|e| anyhow::anyhow!(e))?;
    match cmd {
        "sort" => cmd_sort(&flags),
        "serve" => cmd_serve(&flags),
        "stream" => cmd_stream(&flags),
        "sim" => cmd_sim(&flags),
        "vopr" => cmd_vopr(&flags),
        "cost" => cmd_cost(&flags),
        "info" => cmd_info(&flags),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow::anyhow!(
            "unknown command '{other}' (try `exoshuffle help`)"
        )),
    }
}

const HELP: &str = "\
exoshuffle — Exoshuffle-CloudSort reproduction (shuffle as a library)

USAGE: exoshuffle <COMMAND> [--flag value]...

COMMANDS:
  sort   run a scaled shuffle job end-to-end on the in-process cluster
           --size 256MiB       dataset size (default 64MiB)
           --workers 4         worker nodes (default 4)
           --reducers R        output partitions (must be a multiple of
                               --workers; default chosen by scaling)
           --strategy NAME     two-stage-merge | simple | streaming
           --list-strategies   print registered strategies and exit
           --backend xla|native (default: xla in pjrt builds, else native)
           --artifacts DIR     artifact dir (default ./artifacts)
           --config FILE       TOML config (overrides --size/--workers)
           --no-backpressure   disable merge backpressure (ablation)
           --skew zipf:THETA   generate a Zipf-skewed key distribution
                               (or `uniform`, the default / Indy input)
           --sample-fraction F pre-map sampling: read F of the input
                               shards and install sampled reducer cuts
                               (0 disables; adaptive range partitioning)
           --speculate [MULT]  re-execute stragglers slower than MULT x
                               the running family median on another
                               node (bare flag: MULT = 2.0)
           --chaos-kill N@C    kill node N after the C-th commit of the
                               sort (lineage recovery demo; repeatable
                               via comma: 1@10,2@40)
           --chaos-slow N@C:F  slow node N to F x task duration after
                               the C-th commit (straggler injection;
                               comma-repeatable)
           --chaos-s3-latency MS@C
                               add MS ms to every task after the C-th
                               commit (degraded S3; comma-repeatable)
           --scale-event N@C   scale the fleet to N available nodes
                               after the C-th commit (deterministic
                               elastic event; comma-repeatable)
  serve  run N concurrent jobs through one shared JobService
           --jobs 4            number of concurrent jobs
           --mix a,b,c         strategies assigned round-robin
                               (default two-stage-merge)
           --size 32MiB        dataset size per job
           --workers 4         worker nodes of the shared runtime
           --stagger-ms 0      delay between submissions
           --weights 1,2,...   per-job fair-share weights (round-robin)
           --max-in-flight N   per-job quota on executing tasks
           --autoscale         start at --min-nodes and let the
                               cost-aware autoscaler grow/shrink the
                               fleet (node-count timeline + dollars
                               saved vs a fleet pinned at --max-nodes)
           --min-nodes 1       autoscaler floor
           --max-nodes W       autoscaler ceiling (default --workers)
           --backend xla|native
  stream run a continuous repartitioning job: a seeded arrival stream
         windowed into epochs, each epoch shuffled through the batch
         machinery (epoch N+1 admits while epoch N drains), sealed in
         order with ingest->sealed latency tracked against an SLO
           --epochs 4          epochs to run before stopping
           --epoch-records 20000  records per epoch window
           --arrival-rate R    records/second of the ingest stream
                               (default: one window per second;
                               0 = pre-filled backlog)
           --slo-ms MS         per-epoch latency objective (violations
                               are counted, not fatal)
           --workers 4         worker nodes of the shared runtime
           --strategy NAME     two-stage-merge | simple | streaming
           --backend xla|native
           --skew zipf:THETA   key distribution of the arrivals
           --burst-every N     every Nth epoch arrives at
           --burst-factor F    F x the steady rate (shorter window)
           --pipeline-depth 2  epochs allowed open at once (1 = serial)
           --speculate [MULT]  straggler re-execution inside epochs
           --chaos-kill N@C    kill node N after the C-th commit of the
                               chaos epoch (comma-repeatable); also
                               --chaos-slow, --chaos-s3-latency as in
                               `sort`
           --chaos-epoch E     epoch the chaos plan arms on (default:
                               mid-stream)
           --sim-seed S        run on the deterministic simulation
                               backend (virtual time) instead of threads
           --verify-batch      re-run every epoch as a one-shot batch
                               sort and check byte-identity
  sim    simulate the full 100 TB benchmark (Table 1 / Figure 1)
           --runs 3            number of runs (Table 1 rows)
           --strategy NAME     topology to replay (default two-stage-merge)
           --jobs N            also estimate N-tenant contention
           --autoscale         elastic-fleet mode: replay the run under
                               a scaling fleet (capacity ramp + straggler
                               drains) and price it vs the pinned fleet
           --min-nodes W/4     elastic ramp floor
           --provision-secs 60 node provisioning cadence of the ramp
           --fig1-csv FILE     write Figure 1 utilization CSV
           --stream            also estimate the benchmark as one epoch
                               of a continuous stream: per-epoch latency
                               vs arrival rate and the backlog cliff
           --arrival-rate R    records/second for --stream (default:
                               the max sustainable rate x 0.8)
           --epochs 8          epochs for the --stream estimate
  vopr   sweep seeds x strategies x chaos plans over the deterministic
         simulation runtime (distfut::sim); every run executes the real
         shuffle pipeline on a virtual clock and is byte-checked against
         an unfaulted reference plus liveness/no-leak invariants. One
         JSON line per run; failures print a one-line repro command.
           --seed-start 0      first seed (inclusive)
           --seed-end 8        last seed (exclusive)
           --strategies all    comma list or `all`
                               (two-stage-merge,simple,streaming)
           --chaos all         comma list or `all`
                               (none,kill,drain,slow — `slow` cells run
                               with speculation enabled)
           --workers 3         fleet size per run (>= 2)
           --size 2MiB         dataset size per run
           --workload sort     `sort` (one-shot job per cell) or
                               `stream` (3-epoch StreamJob per cell;
                               chaos arms mid-stream, every epoch is
                               byte-checked against the unfaulted
                               stream's per-epoch digests)
           --out FILE          append JSONL results here (else stdout)
           --resume            skip (seed,strategy,chaos) cells already
                               recorded in --out (CI shard restarts)
  cost   print the Table 2 cost breakdown
           --hours 1.4939      job completion hours
           --reduce-hours 0.5194
           --workers 40  --gets 6000000  --puts 1000000
  info   print artifact manifest and backend info
           --artifacts DIR
";

/// Print the strategy registry (for `--list-strategies`). With
/// `sim_only`, restrict to strategies the discrete-event simulator can
/// replay, so `sim --list-strategies` never advertises a name that
/// `sim --strategy` rejects.
fn print_strategies(sim_only: bool) {
    println!(
        "{}",
        if sim_only {
            "strategies with a simulator topology:"
        } else {
            "registered shuffle strategies:"
        }
    );
    for s in list_strategies() {
        if sim_only && SimStrategy::from_name(s.name()).is_none() {
            continue;
        }
        println!("  {:<16} stages {:?}", s.name(), s.stage_names());
        println!("  {:<16}   {}", "", s.describe());
    }
}

/// Parse `--chaos-kill` values: `NODE@COMMITS`, comma-separated for
/// multiple kills (e.g. `1@10,2@40`).
fn parse_chaos_kills(value: &str) -> Result<ChaosPlan, String> {
    let mut plan = ChaosPlan::new();
    for part in value.split(',') {
        let (node, commits) = part
            .split_once('@')
            .ok_or_else(|| format!("--chaos-kill wants NODE@COMMITS, got '{part}'"))?;
        let node: usize = node
            .trim()
            .parse()
            .map_err(|_| format!("bad node '{node}' in --chaos-kill"))?;
        let commits: u64 = commits
            .trim()
            .parse()
            .map_err(|_| format!("bad commit count '{commits}' in --chaos-kill"))?;
        plan = plan.kill_node(node, commits);
    }
    Ok(plan)
}

/// Parse `--chaos-slow` values onto `plan`: `NODE@COMMITS:FACTOR`,
/// comma-separated (e.g. `1@10:8,2@40:4` — slow node 1 to 8x task
/// duration after commit 10, node 2 to 4x after commit 40).
fn parse_chaos_slow(
    value: &str,
    mut plan: ChaosPlan,
) -> Result<ChaosPlan, String> {
    for part in value.split(',') {
        let (node, rest) = part.split_once('@').ok_or_else(|| {
            format!("--chaos-slow wants NODE@COMMITS:FACTOR, got '{part}'")
        })?;
        let (commits, factor) = rest.split_once(':').ok_or_else(|| {
            format!("--chaos-slow wants NODE@COMMITS:FACTOR, got '{part}'")
        })?;
        let node: usize = node
            .trim()
            .parse()
            .map_err(|_| format!("bad node '{node}' in --chaos-slow"))?;
        let commits: u64 = commits.trim().parse().map_err(|_| {
            format!("bad commit count '{commits}' in --chaos-slow")
        })?;
        let factor: f64 = factor
            .trim()
            .parse()
            .map_err(|_| format!("bad factor '{factor}' in --chaos-slow"))?;
        if !factor.is_finite() || factor < 1.0 {
            return Err(format!(
                "--chaos-slow factor must be >= 1.0, got '{factor}'"
            ));
        }
        plan = plan.slow_node(node, factor, commits);
    }
    Ok(plan)
}

/// Parse `--chaos-s3-latency` values onto `plan`: `MS@COMMITS`, comma-
/// separated (e.g. `50@10` — +50ms on every task after commit 10).
fn parse_chaos_s3_latency(
    value: &str,
    mut plan: ChaosPlan,
) -> Result<ChaosPlan, String> {
    for part in value.split(',') {
        let (ms, commits) = part.split_once('@').ok_or_else(|| {
            format!("--chaos-s3-latency wants MS@COMMITS, got '{part}'")
        })?;
        let ms: u64 = ms.trim().parse().map_err(|_| {
            format!("bad latency '{ms}' in --chaos-s3-latency")
        })?;
        let commits: u64 = commits.trim().parse().map_err(|_| {
            format!("bad commit count '{commits}' in --chaos-s3-latency")
        })?;
        plan = plan.s3_latency(ms, commits);
    }
    Ok(plan)
}

/// Parse `--skew` values: `uniform` or `zipf:THETA` (0 < theta).
fn parse_skew(value: &str) -> Result<Skew, String> {
    if value.trim() == "uniform" {
        return Ok(Skew::Uniform);
    }
    let theta = value
        .trim()
        .strip_prefix("zipf:")
        .ok_or_else(|| {
            format!("--skew wants 'uniform' or 'zipf:THETA', got '{value}'")
        })?
        .parse::<f64>()
        .map_err(|_| format!("bad theta in --skew '{value}'"))?;
    Ok(Skew::Zipf(theta))
}

/// Parse `--speculate`: bare (`true`) means the default 2.0 multiplier,
/// otherwise the value is the straggler multiplier itself.
fn parse_speculate(value: &str) -> Result<f64, String> {
    if value == "true" {
        return Ok(2.0);
    }
    value
        .parse::<f64>()
        .map_err(|_| format!("bad multiplier in --speculate '{value}'"))
}

/// Parse `--scale-event` values onto `plan`: `NODES@COMMITS`, comma-
/// separated (e.g. `6@100,2@400` — grow to 6 available nodes after
/// commit 100, shrink to 2 after commit 400).
fn parse_scale_events(
    value: &str,
    mut plan: ChaosPlan,
) -> Result<ChaosPlan, String> {
    for part in value.split(',') {
        let (nodes, commits) = part.split_once('@').ok_or_else(|| {
            format!("--scale-event wants NODES@COMMITS, got '{part}'")
        })?;
        let nodes: usize = nodes
            .trim()
            .parse()
            .map_err(|_| format!("bad node count '{nodes}' in --scale-event"))?;
        let commits: u64 = commits.trim().parse().map_err(|_| {
            format!("bad commit count '{commits}' in --scale-event")
        })?;
        plan = plan.scale_to(nodes, commits);
    }
    Ok(plan)
}

/// Render a live-node-count timeline as a fixed-width strip, one digit
/// per time bin (`#` above 9 nodes, space before the first sample).
fn render_node_strip(timeline: &[(f64, usize)], end: f64, bins: usize) -> String {
    let mut out = String::with_capacity(bins);
    let end = end.max(1e-9);
    for b in 0..bins {
        let t = (b as f64 + 0.5) / bins as f64 * end;
        let count = timeline
            .iter()
            .take_while(|&&(at, _)| at <= t)
            .last()
            .map(|&(_, n)| n);
        out.push(match count {
            None => ' ',
            Some(n) if n > 9 => '#',
            Some(n) => std::char::from_digit(n as u32, 10).unwrap_or('#'),
        });
    }
    out
}

fn cmd_sort(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    if flags.contains_key("list-strategies") {
        print_strategies(false);
        return Ok(());
    }
    let mut spec: JobSpec = if let Some(path) = flags.get("config") {
        let text = std::fs::read_to_string(path)?;
        Config::parse(&text)
            .and_then(|c| c.to_job_spec())
            .map_err(|e| anyhow::anyhow!(e))?
    } else {
        let size = flags
            .get("size")
            .map(|s| parse_bytes(s))
            .transpose()
            .map_err(|e| anyhow::anyhow!(e))?
            .unwrap_or(64 << 20);
        let workers: usize = flags
            .get("workers")
            .map(|w| w.parse())
            .transpose()?
            .unwrap_or(4);
        let mut s = JobSpec::scaled(size, workers);
        if let Some(r) = flags.get("reducers") {
            let r: usize = r
                .parse()
                .map_err(|_| anyhow::anyhow!("bad --reducers '{r}'"))?;
            // validated here, not deep in worker_cuts()'s assert: an
            // indivisible count used to panic mid-run
            if r == 0 || r % workers != 0 {
                return Err(anyhow::anyhow!(
                    "--reducers ({r}) must be a positive multiple of \
                     --workers ({workers})"
                ));
            }
            s.n_output_partitions = r;
        }
        if flags.get("no-backpressure").map(|v| v == "true") == Some(true) {
            s.backpressure = false;
        }
        s
    };
    if let Some(v) = flags.get("skew") {
        spec.skew = parse_skew(v).map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(v) = flags.get("sample-fraction") {
        spec.sample_fraction = v
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --sample-fraction '{v}'"))?;
    }
    if let Some(v) = flags.get("speculate") {
        spec.speculate =
            Some(parse_speculate(v).map_err(|e| anyhow::anyhow!(e))?);
    }
    spec.check()
        .map_err(|e| anyhow::anyhow!("invalid job spec: {e}"))?;
    let artifacts = flags
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"));
    let backend = Backend::from_name(
        flags
            .get("backend")
            .map(|s| s.as_str())
            .unwrap_or(DEFAULT_BACKEND),
        &artifacts,
    )?;
    let strategy_name = flags
        .get("strategy")
        .map(|s| s.as_str())
        .unwrap_or("two-stage-merge");
    let strategy = strategy_by_name(strategy_name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown strategy '{strategy_name}' (try --list-strategies)"
        )
    })?;
    println!(
        "sorting {} across {} workers (M={}, R={}, backend={}, strategy={})",
        human_bytes(spec.total_bytes),
        spec.n_workers(),
        spec.n_input_partitions,
        spec.n_output_partitions,
        backend.name(),
        strategy.name(),
    );
    let mut job = ShuffleJob::new(spec.clone())
        .strategy_arc(strategy)
        .backend(backend);
    let mut plan = ChaosPlan::new();
    if let Some(kills) = flags.get("chaos-kill") {
        plan = parse_chaos_kills(kills).map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(scales) = flags.get("scale-event") {
        plan = parse_scale_events(scales, plan)
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(slows) = flags.get("chaos-slow") {
        plan = parse_chaos_slow(slows, plan).map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(lat) = flags.get("chaos-s3-latency") {
        plan = parse_chaos_s3_latency(lat, plan)
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    let scale_ceiling = plan
        .triggers
        .iter()
        .map(|t| match t.event {
            ChaosEvent::ScaleTo(n) => n,
            _ => 0,
        })
        .max()
        .unwrap_or(0);
    if !plan.triggers.is_empty() {
        job = job.chaos(plan);
    }
    // a --scale-event above --workers needs fleet headroom the one-shot
    // run() wrapper (whose fleet is pinned at the spec's worker count)
    // cannot provide
    let report = if scale_ceiling > spec.n_workers() {
        let mut cfg = ServiceConfig::for_spec(&spec);
        cfg.max_nodes = scale_ceiling;
        let service = JobService::new(cfg);
        let result = service.submit(job).and_then(|h| h.wait());
        service.shutdown();
        result?
    } else {
        job.run()?
    };
    println!("generate:     {:>8.2}s", report.gen_secs);
    if report.sampled_keys > 0 {
        println!(
            "sample:       {:>8.2}s  ({} keys -> sampled reducer cuts)",
            report.sample_secs, report.sampled_keys
        );
    }
    for stage in &report.stages {
        println!("{:<13} {:>8.2}s", format!("{}:", stage.name), stage.secs);
    }
    println!("total:        {:>8.2}s  ({})", report.total_secs,
        human_secs(report.total_secs));
    println!(
        "tasks: {} map, {} merge, {} reduce | retries: {}",
        report.n_map_tasks,
        report.n_merge_tasks,
        report.n_reduce_tasks,
        report.task_counts.1
    );
    println!(
        "s3: {} GETs, {} PUTs | transfers: {} ({}) | spills: {}",
        report.s3.get_requests,
        report.s3.put_requests,
        report.store.transfers,
        human_bytes(report.store.transfer_bytes),
        report.store.spills,
    );
    for rec in &report.chaos {
        println!(
            "chaos: t={:.2}s commit#{} {:?} -> {}",
            rec.at_secs, rec.after_commits, rec.event, rec.outcome
        );
    }
    if report.recovery.nodes_killed > 0 {
        println!(
            "recovery: {} node(s) killed, {} objects lost, \
             {} tasks resubmitted, {} rerouted, {} unrecoverable",
            report.recovery.nodes_killed,
            report.recovery.objects_lost,
            report.recovery.tasks_resubmitted,
            report.recovery.tasks_rerouted,
            report.recovery.objects_unrecoverable,
        );
    }
    if report.speculation.tasks_speculated > 0 {
        println!(
            "speculation: {} straggler(s) raced | wins: {} speculative, \
             {} original | {} duplicate commits discarded",
            report.speculation.tasks_speculated,
            report.speculation.speculative_wins,
            report.speculation.original_wins,
            report.store.duplicate_commits,
        );
    }
    if report.node_timeline.len() > 1 {
        let end = report
            .events
            .iter()
            .map(|e| e.end)
            .chain(report.node_timeline.iter().map(|&(t, _)| t))
            .fold(0.0f64, f64::max);
        println!(
            "nodes over time: |{}| ({} at end, {} migrated in drains)",
            render_node_strip(&report.node_timeline, end, 48),
            report.node_timeline.last().map(|&(_, n)| n).unwrap_or(0),
            report.store.drain_migrations,
        );
    }
    println!(
        "validation: {} (records={}, checksum={:#x})",
        if report.validation.valid { "PASS" } else { "FAIL" },
        report.validation.summary.records,
        report.validation.summary.checksum,
    );
    let hist = &report.validation.partition_records;
    if !hist.is_empty() {
        let total: u64 = hist.iter().sum();
        let max = hist.iter().copied().max().unwrap_or(0);
        println!(
            "partitions: {} ranges, skew factor {:.2} \
             (max {} records, mean {:.0})",
            hist.len(),
            report.validation.skew_factor(),
            max,
            total as f64 / hist.len() as f64,
        );
    }
    if flags.get("events").map(|v| v == "true") == Some(true) {
        for family in ["gen", "map", "merge", "reduce", "validate"] {
            let durs: Vec<f64> = report
                .events
                .iter()
                .filter(|e| e.ok && e.name.starts_with(family))
                .map(|e| e.duration())
                .collect();
            let lo = report
                .events
                .iter()
                .filter(|e| e.name.starts_with(family))
                .map(|e| e.start)
                .fold(f64::INFINITY, f64::min);
            let hi = report
                .events
                .iter()
                .filter(|e| e.name.starts_with(family))
                .map(|e| e.end)
                .fold(0.0f64, f64::max);
            println!(
                "  {family:<9} n={:<5} busy={:>8.2}s span={:>8.2}s mean={:>7.3}s",
                durs.len(),
                durs.iter().sum::<f64>(),
                hi - lo,
                exoshuffle::util::stats::mean(&durs),
            );
        }
        // pipelining visibility: wall time two stage families overlap
        // (≈0 under a stage barrier, > 0 under --strategy streaming)
        for (a, b) in [("map", "merge"), ("merge", "reduce"), ("map", "reduce")]
        {
            println!(
                "  overlap {a:>6}/{b:<7} {:>8.2}s",
                exoshuffle::metrics::overlap_secs(&report.events, a, b)
            );
        }
        // timelines cover the timed sort only — gen/validate are untimed
        let sort_events: Vec<_> = report
            .events
            .iter()
            .filter(|e| {
                ["map-", "merge-", "reduce-"]
                    .iter()
                    .any(|p| e.name.starts_with(p))
            })
            .cloned()
            .collect();
        let timelines = exoshuffle::metrics::per_node_timelines(
            &sort_events,
            spec.n_workers(),
        );
        for t in &timelines {
            println!(
                "  node {:<2} busy={:>8.2}s util={:>5.1}% retries={} recoveries={}",
                t.node,
                t.busy_secs(),
                t.utilization() * 100.0,
                t.retried_attempts(),
                t.recovery_attempts(),
            );
        }
    }
    if !report.validation.valid {
        return Err(anyhow::anyhow!("output validation failed"));
    }
    Ok(())
}

/// The multi-tenant workload driver: one shared `JobService`, N
/// staggered jobs with a strategy mix, per-job reports and a fairness
/// summary (share of task slots per job over the contended window).
fn cmd_serve(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let jobs: usize = flags
        .get("jobs")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(4);
    let workers: usize = flags
        .get("workers")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(4);
    let size = flags
        .get("size")
        .map(|s| parse_bytes(s))
        .transpose()
        .map_err(|e| anyhow::anyhow!(e))?
        .unwrap_or(32 << 20);
    let stagger_ms: u64 = flags
        .get("stagger-ms")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(0);
    let mix: Vec<String> = flags
        .get("mix")
        .map(|m| m.split(',').map(|s| s.trim().to_string()).collect())
        .unwrap_or_else(|| vec!["two-stage-merge".to_string()]);
    let weights: Vec<f64> = match flags.get("weights") {
        Some(w) => w
            .split(',')
            .map(|v| v.trim().parse::<f64>())
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("bad --weights: {e}"))?,
        None => vec![1.0],
    };
    let max_in_flight: Option<usize> = flags
        .get("max-in-flight")
        .map(|v| v.parse())
        .transpose()?;
    let artifacts = flags
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"));
    let backend = Backend::from_name(
        flags
            .get("backend")
            .map(|s| s.as_str())
            .unwrap_or(DEFAULT_BACKEND),
        &artifacts,
    )?;

    let autoscale = flags.get("autoscale").map(|v| v == "true") == Some(true);
    let min_nodes: usize = flags
        .get("min-nodes")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(1)
        .max(1);
    let max_nodes: usize = flags
        .get("max-nodes")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(workers);
    if autoscale && max_nodes < workers {
        // jobs plan for --workers nodes and would be rejected at
        // submission anyway; fail here with the clearer message rather
        // than silently raising the user's spend ceiling
        return Err(anyhow::anyhow!(
            "--max-nodes {max_nodes} is below --workers {workers}; jobs \
             plan for {workers} workers, so the fleet ceiling cannot be \
             smaller"
        ));
    }

    let spec = JobSpec::scaled(size, workers);
    let mut svc_cfg = ServiceConfig::for_spec(&spec);
    if autoscale {
        svc_cfg.n_nodes = min_nodes;
        svc_cfg.max_nodes = max_nodes;
    }
    let service = JobService::new(svc_cfg);
    let scaler = autoscale.then(|| {
        Autoscaler::start(
            service.runtime().clone(),
            AutoscalerConfig {
                min_nodes,
                max_nodes,
                ..AutoscalerConfig::default()
            },
        )
    });
    if autoscale {
        println!(
            "serving {jobs} concurrent jobs of {} each on an elastic \
             {min_nodes}..{max_nodes}-node runtime (mix: {})",
            human_bytes(size),
            mix.join(","),
        );
    } else {
        println!(
            "serving {jobs} concurrent jobs of {} each on a shared \
             {workers}-node runtime (mix: {})",
            human_bytes(size),
            mix.join(","),
        );
    }
    let mut handles = Vec::with_capacity(jobs);
    for i in 0..jobs {
        let strategy_name = &mix[i % mix.len()];
        let strategy = strategy_by_name(strategy_name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown strategy '{strategy_name}' in --mix \
                 (try sort --list-strategies)"
            )
        })?;
        let mut job = ShuffleJob::new(spec.clone())
            .strategy_arc(strategy)
            .backend(backend.clone())
            .name(format!("job-{i}-{strategy_name}"))
            .priority(weights[i % weights.len()]);
        if let Some(cap) = max_in_flight {
            job = job.max_in_flight(cap);
        }
        handles.push(job.submit(&service)?);
        if stagger_ms > 0 && i + 1 < jobs {
            std::thread::sleep(std::time::Duration::from_millis(stagger_ms));
        }
    }

    let mut failed = 0usize;
    for h in &handles {
        match h.wait() {
            Ok(report) => println!(
                "{:<24} {:<16} total {:>7.2}s  validation {}",
                report.name,
                report.strategy,
                report.total_secs,
                if report.validation.valid { "PASS" } else { "FAIL" },
            ),
            Err(e) => {
                failed += 1;
                println!("{:<24} FAILED: {e:#}", h.name());
            }
        }
    }

    let job_name = |job: exoshuffle::distfut::JobId| {
        handles
            .iter()
            .find(|h| h.id() == job)
            .map(|h| h.name().to_string())
            .unwrap_or_else(|| job.to_string())
    };
    let fairness = service.fairness();
    if fairness.per_job.len() >= 2 {
        println!(
            "\nfairness over the contended window [{:.2}s, {:.2}s]:",
            fairness.window.0, fairness.window.1
        );
        for share in &fairness.per_job {
            println!(
                "  {:<24} {:>5.1}% of task slots ({:.2} slot-secs)",
                job_name(share.job),
                share.share * 100.0,
                share.busy_slot_secs,
            );
        }
        println!("  min share: {:.1}%", fairness.min_share() * 100.0);
        // per-job share-of-slots over time: each cell is 1/48 of the
        // run, shaded by the job's fraction of the slots granted then
        let events: Vec<exoshuffle::metrics::TaskEvent> = handles
            .iter()
            .filter_map(|h| h.report())
            .flat_map(|r| r.events)
            .collect();
        let series = exoshuffle::metrics::slot_share_series(&events, 48);
        if !series.is_empty() {
            println!("share of task slots over time:");
            for (job, shares) in &series {
                let cells: String = shares
                    .iter()
                    .map(|s| {
                        if *s <= 0.01 {
                            ' '
                        } else if *s < 0.25 {
                            '.'
                        } else if *s < 0.5 {
                            '-'
                        } else if *s < 0.75 {
                            '+'
                        } else {
                            '#'
                        }
                    })
                    .collect();
                println!("  {:<24} |{cells}|", job_name(*job));
            }
        }
    }
    let stats = service.runtime().store_stats();
    println!(
        "runtime: {} transfers ({}), {} spills, {} node stalls, {} job stalls",
        stats.transfers,
        human_bytes(stats.transfer_bytes),
        stats.spills,
        stats.backpressure_stalls,
        stats.job_backpressure_stalls,
    );
    if let Some(scaler) = &scaler {
        scaler.stop();
        let rt = service.runtime();
        let now = rt.now();
        println!("\nautoscaler decisions:");
        for e in scaler.events() {
            println!(
                "  t={:>6.2}s {} node {:<2} -> {} nodes  ({})",
                e.at_secs,
                if e.scale_up { "+join " } else { "-drain" },
                e.node,
                e.nodes_after,
                e.reason,
            );
        }
        println!(
            "node count over time: |{}|",
            render_node_strip(&rt.node_count_timeline(), now, 48)
        );
        // liveness-weighted: per-node averages weight by time-in-fleet,
        // so short-lived burst nodes don't skew the cluster number
        let events: Vec<exoshuffle::metrics::TaskEvent> = handles
            .iter()
            .filter_map(|h| h.report())
            .flat_map(|r| r.events)
            .collect();
        let liveness = rt.node_liveness(now);
        let fleet_util =
            exoshuffle::metrics::fleet_utilization(&events, &liveness);
        let per_node = exoshuffle::metrics::per_node_live_utilization(
            &events, &liveness,
        );
        let live_secs: Vec<f64> = liveness
            .iter()
            .map(|iv| iv.iter().map(|(a, b)| b - a).sum())
            .collect();
        println!(
            "fleet utilization (liveness-weighted): mean {:.1}%, \
             median node {:.1}%",
            fleet_util * 100.0,
            exoshuffle::util::stats::weighted_percentile(
                &per_node, &live_secs, 50.0
            ) * 100.0,
        );
        let cost = scaler.cost_report(&CostModel::paper());
        println!(
            "fleet cost (paper worker rate): elastic ${:.4} vs \
             pinned-at-{max_nodes} ${:.4} — saved ${:.4} ({:.0}%)",
            cost.elastic_dollars,
            cost.fixed_dollars,
            cost.saved_dollars(),
            cost.saved_fraction() * 100.0,
        );
        println!(
            "drains migrated {} objects ({}); nothing lost",
            stats.drain_migrations,
            human_bytes(stats.drain_migrated_bytes),
        );
    }
    service.shutdown();
    if failed > 0 {
        return Err(anyhow::anyhow!("{failed} job(s) failed"));
    }
    Ok(())
}

/// The continuous repartitioning driver: a seeded arrival stream
/// windowed into epochs, each epoch shuffled through the batch
/// machinery on a shared `JobService` (epoch N+1 admits while epoch N
/// drains), sealed in watermark order with ingest→sealed latency
/// tracked against an optional SLO.
fn cmd_stream(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let epochs: usize = flags
        .get("epochs")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(4);
    let epoch_records: u64 = flags
        .get("epoch-records")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(20_000);
    if epoch_records == 0 {
        return Err(anyhow::anyhow!("--epoch-records must be positive"));
    }
    // default: one window per second (pipelining has real slack to hide)
    let arrival_rate: f64 = flags
        .get("arrival-rate")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(epoch_records as f64);
    if !arrival_rate.is_finite() || arrival_rate < 0.0 {
        return Err(anyhow::anyhow!(
            "--arrival-rate must be a non-negative rate, got {arrival_rate}"
        ));
    }
    let workers: usize = flags
        .get("workers")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(4);
    let strategy_name = flags
        .get("strategy")
        .map(|s| s.as_str())
        .unwrap_or("two-stage-merge");
    let strategy = strategy_by_name(strategy_name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown strategy '{strategy_name}' (try sort --list-strategies)"
        )
    })?;
    let artifacts = flags
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"));
    let backend = Backend::from_name(
        flags
            .get("backend")
            .map(|s| s.as_str())
            .unwrap_or(DEFAULT_BACKEND),
        &artifacts,
    )?;

    let mut source = IngestSource::new(42, arrival_rate, epoch_records);
    if let Some(v) = flags.get("skew") {
        source.skew = parse_skew(v).map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(v) = flags.get("burst-every") {
        source.burst_every = v
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --burst-every '{v}'"))?;
    }
    if let Some(v) = flags.get("burst-factor") {
        let f: f64 = v
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --burst-factor '{v}'"))?;
        if !f.is_finite() || f < 1.0 {
            return Err(anyhow::anyhow!(
                "--burst-factor must be >= 1.0, got '{v}'"
            ));
        }
        source.burst_factor = f;
    }

    let mut job = StreamJob::new(source, workers)
        .epochs(epochs)
        .strategy_arc(strategy)
        .backend(backend);
    if let Some(v) = flags.get("slo-ms") {
        let ms: f64 = v
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --slo-ms '{v}'"))?;
        job = job.slo_ms(ms);
    }
    if let Some(v) = flags.get("sim-seed") {
        let seed: u64 = v
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --sim-seed '{v}'"))?;
        job = job.sim_seed(seed);
    }
    if let Some(v) = flags.get("pipeline-depth") {
        let depth: usize = v
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --pipeline-depth '{v}'"))?;
        job = job.pipeline_depth(depth);
    }
    if flags.get("verify-batch").map(|v| v == "true") == Some(true) {
        job = job.verify_batch(true);
    }
    if let Some(v) = flags.get("speculate") {
        job = job
            .speculate(parse_speculate(v).map_err(|e| anyhow::anyhow!(e))?);
    }
    let mut plan = ChaosPlan::new();
    if let Some(kills) = flags.get("chaos-kill") {
        plan = parse_chaos_kills(kills).map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(slows) = flags.get("chaos-slow") {
        plan = parse_chaos_slow(slows, plan).map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(lat) = flags.get("chaos-s3-latency") {
        plan = parse_chaos_s3_latency(lat, plan)
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    if !plan.triggers.is_empty() {
        job = job.chaos(plan);
    }
    if let Some(v) = flags.get("chaos-epoch") {
        let e: usize = v
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --chaos-epoch '{v}'"))?;
        job = job.chaos_epoch(e);
    }

    println!(
        "streaming {epochs} epochs of {} ({} records) at {arrival_rate:.0} \
         records/s across {workers} workers (strategy={strategy_name})",
        human_bytes(epoch_records * exoshuffle::sortlib::RECORD_SIZE as u64),
        epoch_records,
    );
    let report = job.run()?;
    for ep in &report.epochs {
        println!(
            "epoch #{:<2} window {:>6.2}s  sealed@{:>7.2}s  \
             latency {:>7.2}s{}{}{}{}",
            ep.epoch,
            ep.window_secs,
            ep.sealed_secs,
            ep.latency_secs,
            if ep.slo_violated { "  SLO-VIOLATION" } else { "" },
            if ep.report.validation.valid { "" } else { "  INVALID" },
            if ep.store_purged { "" } else { "  STORE-LEAK" },
            match ep.batch_identical {
                Some(true) => "  batch-identical",
                Some(false) => "  BATCH-MISMATCH",
                None => "",
            },
        );
    }
    println!(
        "watermark: {} epochs ({} records, {}) sealed in {}  ({}/s)",
        report.watermark,
        report.total_records,
        human_bytes(report.total_bytes),
        human_secs(report.total_secs),
        human_bytes(report.bytes_per_sec() as u64),
    );
    let l = &report.latency;
    println!(
        "latency: p50 {:.2}s  p95 {:.2}s  p99 {:.2}s  max {:.2}s",
        l.p50_secs, l.p95_secs, l.p99_secs, l.max_secs,
    );
    if let Some(slo) = l.slo_secs {
        println!(
            "slo: {:.0}ms -> {} violation(s) in {} epoch(s) ({:.0}%)",
            slo * 1000.0,
            l.violations,
            l.n,
            l.violation_rate() * 100.0,
        );
    }
    println!(
        "pipeline: {:.2}s of epoch overlap, max {} epoch(s) open",
        report.pipeline_overlap_secs, report.max_open_epochs,
    );
    if !report.all_valid() {
        return Err(anyhow::anyhow!("an epoch failed output validation"));
    }
    if !report.all_purged() {
        return Err(anyhow::anyhow!(
            "store entries survived epoch retirement"
        ));
    }
    if report
        .epochs
        .iter()
        .any(|e| e.batch_identical == Some(false))
    {
        return Err(anyhow::anyhow!(
            "an epoch's output diverged from its batch re-run"
        ));
    }
    Ok(())
}

fn cmd_sim(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    if flags.contains_key("list-strategies") {
        print_strategies(true);
        return Ok(());
    }
    let runs: usize = flags
        .get("runs")
        .map(|r| r.parse())
        .transpose()?
        .unwrap_or(3);
    let strategy_name = flags
        .get("strategy")
        .map(|s| s.as_str())
        .unwrap_or("two-stage-merge");
    let strategy = SimStrategy::from_name(strategy_name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown strategy '{strategy_name}' (try --list-strategies)"
        )
    })?;
    let mut rows = Vec::new();
    println!(
        "simulating the 100 TB CloudSort benchmark \
         ({runs} runs, strategy={})\n",
        strategy.name()
    );
    for run in 0..runs {
        let mut cfg = SimConfig::paper_100tb();
        cfg.strategy = strategy;
        cfg.seed = 1 + run as u64;
        let r = simulate(&cfg);
        println!(
            "run #{}: map&shuffle {:.0}s  reduce {:.0}s  total {:.0}s  \
             (map {:.1}s, dl {:.1}s, merge {:.1}s, reduce {:.1}s)",
            run + 1,
            r.map_shuffle_secs,
            r.reduce_secs,
            r.total_secs,
            r.mean_map_secs,
            r.mean_map_download_secs,
            r.mean_merge_secs,
            r.mean_reduce_secs,
        );
        if run == 0 {
            if let Some(path) = flags.get("fig1-csv") {
                std::fs::write(path, r.utilization.to_csv())?;
                println!("  wrote Figure 1 CSV to {path}");
            }
            println!("{}", r.utilization.to_ascii(72));
        }
        rows.push(r);
    }
    let avg = |f: fn(&exoshuffle::sim::SimResult) -> f64| {
        rows.iter().map(f).sum::<f64>() / rows.len() as f64
    };
    println!(
        "average: map&shuffle {:.0}s  reduce {:.0}s  total {:.0}s  \
         (paper: 3508s / 1870s / 5378s with two-stage-merge)",
        avg(|r| r.map_shuffle_secs),
        avg(|r| r.reduce_secs),
        avg(|r| r.total_secs),
    );
    // Multi-tenant contention model (the JobService at paper scale)
    if let Some(jobs) = flags.get("jobs") {
        let jobs: usize = jobs.parse()?;
        let mut cfg = SimConfig::paper_100tb();
        cfg.strategy = strategy;
        let mut tenants = vec![1usize];
        let mut n = 2;
        while n < jobs {
            tenants.push(n);
            n *= 2;
        }
        if jobs > 1 {
            tenants.push(jobs);
        }
        println!("\nmulti-job contention (fair-shared cluster):");
        for n in tenants {
            let e = estimate_multi_job(&cfg, n);
            println!(
                "  {n:>2} tenants: per-job {:>7.0}s ({:>4.2}x solo), \
                 aggregate {}/s",
                e.per_job_secs,
                e.slowdown,
                human_bytes(e.aggregate_bytes_per_sec as u64),
            );
        }
    }

    // Elastic-fleet mode: the same run under a scaling fleet
    if flags.get("autoscale").map(|v| v == "true") == Some(true) {
        let mut cfg = SimConfig::paper_100tb();
        cfg.strategy = strategy;
        let w = cfg.spec.n_workers();
        let min_nodes: usize = flags
            .get("min-nodes")
            .map(|v| v.parse())
            .transpose()?
            .unwrap_or((w / 4).max(1));
        let provision_secs: f64 = flags
            .get("provision-secs")
            .map(|v| v.parse())
            .transpose()?
            .unwrap_or(60.0);
        let e = estimate_autoscale(&cfg, min_nodes, provision_secs);
        println!(
            "\nelastic fleet ({min_nodes}..{w} nodes, one join per \
             {provision_secs:.0}s of backlog):"
        );
        println!(
            "  nodes over time: |{}|",
            render_node_strip(&e.node_timeline, e.total_secs, 64)
        );
        println!(
            "  completion: {:.0}s elastic vs {:.0}s fixed ({:+.1}%)",
            e.total_secs,
            e.fixed_total_secs,
            (e.total_secs / e.fixed_total_secs - 1.0) * 100.0,
        );
        println!(
            "  worker compute: {:.0} node-s elastic vs {:.0} node-s \
             pinned — ${:.2} vs ${:.2}, saved ${:.2}",
            e.cost.node_seconds,
            e.cost.fixed_node_seconds,
            e.cost.elastic_dollars,
            e.cost.fixed_dollars,
            e.cost.saved_dollars(),
        );
    }

    // Continuous-stream mode: the benchmark as one epoch of a stream
    if flags.get("stream").map(|v| v == "true") == Some(true) {
        let mut cfg = SimConfig::paper_100tb();
        cfg.strategy = strategy;
        let epochs: usize = flags
            .get("epochs")
            .map(|v| v.parse())
            .transpose()?
            .unwrap_or(8);
        // default arrival: 80% of the sustainable rate (keeps up, with
        // headroom); the probe run also prices the cliff itself
        let probe = estimate_stream(&cfg, epochs, 0.0);
        let rate: f64 = flags
            .get("arrival-rate")
            .map(|v| v.parse())
            .transpose()?
            .unwrap_or(probe.max_sustainable_rate * 0.8);
        let e = estimate_stream(&cfg, epochs, rate);
        println!(
            "\ncontinuous stream ({epochs} epochs at {rate:.0} records/s):"
        );
        println!(
            "  window {:.0}s  process {:.0}s  -> {}",
            e.window_secs,
            e.process_secs,
            if e.backlogged {
                "BACKLOGGED (arrivals outpace the shuffle)"
            } else {
                "keeping up"
            },
        );
        println!(
            "  epoch latency: first {:.0}s, epoch #{} {:.0}s",
            e.steady_latency_secs,
            epochs - 1,
            e.final_latency_secs,
        );
        println!(
            "  max sustainable rate: {:.0} records/s ({}/s sorted)",
            e.max_sustainable_rate,
            human_bytes(
                (e.max_sustainable_rate
                    * exoshuffle::sortlib::RECORD_SIZE as f64)
                    as u64
            ),
        );
    }

    // Table 2 from run #1
    let r = &rows[0];
    let model = CostModel::paper();
    let profile = RunProfile {
        n_workers: 40,
        job_seconds: r.total_secs,
        reduce_seconds: r.reduce_secs,
        data_bytes: 100_000_000_000_000,
        get_requests: r.get_requests,
        put_requests: r.put_requests,
    };
    println!("\n{}", model.render_table2(&profile));
    Ok(())
}

/// Chaos plan of one vopr cell, derived deterministically from the
/// run's seed so the printed repro command reproduces the exact fault.
/// `None` for the unfaulted mode.
fn vopr_chaos_plan(
    mode: &str,
    seed: u64,
    workers: usize,
) -> Option<ChaosPlan> {
    match mode {
        "none" => None,
        // one seeded kill landing inside the sort (commits 3..20)
        "kill" => Some(ChaosPlan::seeded_kills(seed, workers, 1, (3, 20))),
        // one seeded graceful drain; streams 101/102 keep the draw
        // disjoint from seeded_kills' streams and the sim's own draws
        "drain" => {
            let victim = (stream_at(seed, 101) as usize) % workers;
            let after = 3 + stream_at(seed, 102) % 18;
            Some(ChaosPlan::new().drain_node(victim, after))
        }
        // one seeded slow-node (straggler injection, raced by
        // speculation) plus a degraded-S3 tax; streams 103/104 keep the
        // draws disjoint from the kill and drain modes
        "slow" => {
            let victim = (stream_at(seed, 103) as usize) % workers;
            let after = 3 + stream_at(seed, 104) % 18;
            Some(ChaosPlan::new().slow_node(victim, 8.0, after).s3_latency(
                5,
                after + 2,
            ))
        }
        other => unreachable!("chaos mode '{other}' validated at parse"),
    }
}

/// Minimal JSON string escaping for the JSONL output (no serde in the
/// dependency set).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Extract the `(seed, strategy, chaos, workload)` identity of a vopr
/// JSONL line (resume support). `None` for lines that don't carry the
/// seed/strategy/chaos keys; lines from before the stream workload
/// carry no `workload` field and default to `"sort"`.
fn vopr_line_key(line: &str) -> Option<(u64, String, String, String)> {
    let field = |key: &str| -> Option<&str> {
        let tag = format!("\"{key}\":");
        let rest = line[line.find(&tag)? + tag.len()..].trim_start();
        if let Some(stripped) = rest.strip_prefix('"') {
            Some(&stripped[..stripped.find('"')?])
        } else {
            let end = rest.find(|c: char| c == ',' || c == '}')?;
            Some(rest[..end].trim())
        }
    };
    Some((
        field("seed")?.parse().ok()?,
        field("strategy")?.to_string(),
        field("chaos")?.to_string(),
        field("workload").unwrap_or("sort").to_string(),
    ))
}

/// What one vopr cell produced, after invariant checking.
struct VoprOutcome {
    /// Invariant violations (empty = the run passed).
    errors: Vec<String>,
    /// Output digest (0s when the job failed before validation).
    checksum: u64,
    records: u64,
    /// Virtual seconds the simulated run took.
    virtual_secs: f64,
    tasks_executed: u64,
    tasks_retried: u64,
    tasks_resubmitted: u64,
    /// Stragglers that got a speculative sibling (slow-mode cells).
    tasks_speculated: u64,
}

/// Execute one (seed, strategy, chaos) cell on the simulation backend
/// and check its invariants: the job terminates and validates, output
/// bytes match the unfaulted reference, nothing is unrecoverable (the
/// sim records full lineage, so even injected kills must reconstruct),
/// and the store drains to zero entries after retirement.
fn vopr_run_one(
    spec: &JobSpec,
    strategy: &str,
    mode: &str,
    seed: u64,
    reference: Option<(u64, u64)>,
) -> VoprOutcome {
    // `slow` cells run with speculation armed: straggler re-execution is
    // the mechanism under test, and the unfaulted reference (mode
    // "none", speculation off) must still match byte-for-byte.
    let mut spec = spec.clone();
    if mode == "slow" {
        spec.speculate = Some(2.0);
    }
    let spec = &spec;
    let mut cfg = ServiceConfig::for_spec(spec);
    cfg.sim_seed = Some(seed);
    let service = JobService::new(cfg);
    let mut job = ShuffleJob::new(spec.clone())
        .strategy_arc(strategy_by_name(strategy).expect("validated"))
        .backend(Backend::Native)
        .name(format!("vopr-{seed}-{strategy}-{mode}"));
    if let Some(plan) = vopr_chaos_plan(mode, seed, spec.n_workers()) {
        job = job.chaos(plan);
    }
    let result = service.submit(job).and_then(|h| h.wait());
    let rt = service.runtime();
    let recovery = rt.recovery_stats();
    let speculation = rt.speculation_stats();
    let duplicate_commits = rt.store_stats().duplicate_commits;
    let (tasks_executed, tasks_retried) = rt.task_counts();
    let leaked = rt.store_live_entries();
    let virtual_secs = rt.now();

    let mut errors = Vec::new();
    let (mut checksum, mut records) = (0u64, 0u64);
    match &result {
        Ok(report) => {
            checksum = report.validation.summary.checksum;
            records = report.validation.summary.records;
            if !report.validation.valid {
                errors.push(format!(
                    "validation failed: {:?}",
                    report.validation
                ));
            }
            if let Some((rcs, rrecs)) = reference {
                if checksum != rcs || records != rrecs {
                    errors.push(format!(
                        "output diverged from unfaulted reference: \
                         checksum {checksum:#x} vs {rcs:#x}, records \
                         {records} vs {rrecs}"
                    ));
                }
            }
        }
        Err(e) => errors.push(format!("job failed: {e:#}")),
    }
    if recovery.objects_unrecoverable > 0 {
        errors.push(format!(
            "{} objects unrecoverable despite recorded lineage",
            recovery.objects_unrecoverable
        ));
    }
    if leaked > 0 {
        errors.push(format!(
            "{leaked} store entries leaked after job retirement"
        ));
    }
    // on the deterministic backend a speculative race must be bloodless:
    // the losing copy observes the winner's commits and skips its body,
    // so first-commit-wins dedup never actually fires
    if mode == "slow" && duplicate_commits > 0 {
        errors.push(format!(
            "{duplicate_commits} duplicate output commits under \
             speculation (sim races must resolve by body-skip)"
        ));
    }
    service.shutdown();
    VoprOutcome {
        errors,
        checksum,
        records,
        virtual_secs,
        tasks_executed,
        tasks_retried,
        tasks_resubmitted: recovery.tasks_resubmitted,
        tasks_speculated: speculation.tasks_speculated,
    }
}

/// Execute one (seed, strategy, chaos) cell as a 3-epoch stream on the
/// simulation backend and check the streaming invariants: the stream
/// terminates with every epoch sealed (liveness), every epoch
/// validates, per-epoch output bytes match the unfaulted stream's
/// digests (chaos arms mid-stream, at epoch 1), each epoch's store
/// entries are swept at its seal (bounded footprint), and nothing leaks
/// or goes unrecoverable runtime-wide. Returns the outcome plus the
/// per-epoch `(checksum, records)` digests so the first run of a sweep
/// can serve as the reference for the rest.
fn vopr_run_stream(
    spec: &JobSpec,
    strategy: &str,
    mode: &str,
    seed: u64,
    reference: Option<&[(u64, u64)]>,
) -> (VoprOutcome, Vec<(u64, u64)>) {
    const EPOCHS: usize = 3;
    let workers = spec.n_workers();
    // one cell-sized window per epoch, filling in one second
    let records = spec.total_records();
    let mut source = IngestSource::new(42, records as f64, records);
    source.skew = spec.skew;
    let mut cfg = ServiceConfig::for_spec(spec);
    cfg.sim_seed = Some(seed);
    let service = JobService::new(cfg);
    let mut job = StreamJob::new(source, workers)
        .epochs(EPOCHS)
        .strategy_arc(strategy_by_name(strategy).expect("validated"))
        .backend(Backend::Native)
        .name(format!("vopr-stream-{seed}-{strategy}-{mode}"));
    if mode == "slow" {
        // as in the sort workload: straggler re-execution is the
        // mechanism under test in slow cells
        job = job.speculate(2.0);
    }
    if let Some(plan) = vopr_chaos_plan(mode, seed, workers) {
        job = job.chaos(plan).chaos_epoch(1);
    }
    let result = job.run_on(&service);
    let rt = service.runtime();
    let recovery = rt.recovery_stats();
    let speculation = rt.speculation_stats();
    let duplicate_commits = rt.store_stats().duplicate_commits;
    let (tasks_executed, tasks_retried) = rt.task_counts();
    let leaked = rt.store_live_entries();
    let virtual_secs = rt.now();

    let mut errors = Vec::new();
    let mut digests: Vec<(u64, u64)> = Vec::new();
    let (mut checksum, mut records_out) = (0u64, 0u64);
    match &result {
        Ok(report) => {
            for ep in &report.epochs {
                digests.push((ep.checksum, ep.records));
                checksum ^= ep.checksum.rotate_left(ep.epoch as u32);
                if !ep.report.validation.valid {
                    errors.push(format!(
                        "epoch {} failed validation",
                        ep.epoch
                    ));
                }
                if !ep.store_purged {
                    errors.push(format!(
                        "epoch {} store entries not swept at seal",
                        ep.epoch
                    ));
                }
            }
            records_out = report.total_records;
            if report.watermark != EPOCHS {
                errors.push(format!(
                    "watermark stalled at {} of {EPOCHS} epochs",
                    report.watermark
                ));
            }
            if let Some(reference) = reference {
                if digests != reference {
                    errors.push(format!(
                        "per-epoch output diverged from unfaulted \
                         stream: {digests:x?} vs {reference:x?}"
                    ));
                }
            }
        }
        Err(e) => errors.push(format!("stream failed: {e:#}")),
    }
    if recovery.objects_unrecoverable > 0 {
        errors.push(format!(
            "{} objects unrecoverable despite recorded lineage",
            recovery.objects_unrecoverable
        ));
    }
    if leaked > 0 {
        errors.push(format!(
            "{leaked} store entries leaked after the stream"
        ));
    }
    if mode == "slow" && duplicate_commits > 0 {
        errors.push(format!(
            "{duplicate_commits} duplicate output commits under \
             speculation (sim races must resolve by body-skip)"
        ));
    }
    service.shutdown();
    (
        VoprOutcome {
            errors,
            checksum,
            records: records_out,
            virtual_secs,
            tasks_executed,
            tasks_retried,
            tasks_resubmitted: recovery.tasks_resubmitted,
            tasks_speculated: speculation.tasks_speculated,
        },
        digests,
    )
}

/// The vopr seed-sweep fuzzer: every (seed, strategy, chaos) cell runs
/// the real shuffle pipeline on the deterministic simulation runtime
/// and is checked against the strategy's unfaulted reference output.
fn cmd_vopr(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let seed_start: u64 = flags
        .get("seed-start")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(0);
    let seed_end: u64 = flags
        .get("seed-end")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(seed_start + 8);
    if seed_end <= seed_start {
        return Err(anyhow::anyhow!(
            "--seed-end ({seed_end}) must be greater than --seed-start \
             ({seed_start})"
        ));
    }
    let workers: usize = flags
        .get("workers")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(3);
    if workers < 2 {
        return Err(anyhow::anyhow!(
            "--workers must be >= 2: kill/drain chaos needs a surviving \
             node and slow chaos a node to speculate on"
        ));
    }
    let size = flags
        .get("size")
        .map(|s| parse_bytes(s))
        .transpose()
        .map_err(|e| anyhow::anyhow!(e))?
        .unwrap_or(2 << 20);
    let strategy_names: Vec<String> =
        match flags.get("strategies").map(|s| s.as_str()).unwrap_or("all") {
            "all" => list_strategies().iter().map(|s| s.name().to_string()).collect(),
            csv => csv.split(',').map(|s| s.trim().to_string()).collect(),
        };
    for name in &strategy_names {
        if strategy_by_name(name).is_none() {
            return Err(anyhow::anyhow!(
                "unknown strategy '{name}' in --strategies \
                 (try sort --list-strategies)"
            ));
        }
    }
    let chaos_modes: Vec<String> = match flags.get("chaos").map(|s| s.as_str()).unwrap_or("all")
    {
        "all" => ["none", "kill", "drain", "slow"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        csv => csv.split(',').map(|s| s.trim().to_string()).collect(),
    };
    for mode in &chaos_modes {
        if !["none", "kill", "drain", "slow"].contains(&mode.as_str()) {
            return Err(anyhow::anyhow!(
                "unknown chaos mode '{mode}' in --chaos \
                 (none, kill, drain, slow, or all)"
            ));
        }
    }
    let workload = flags.get("workload").map(|s| s.as_str()).unwrap_or("sort");
    if !["sort", "stream"].contains(&workload) {
        return Err(anyhow::anyhow!(
            "unknown workload '{workload}' in --workload (sort or stream)"
        ));
    }
    let out_path = flags.get("out").map(PathBuf::from);
    let resume = flags.get("resume").map(|v| v == "true") == Some(true);
    if resume && out_path.is_none() {
        return Err(anyhow::anyhow!(
            "--resume needs --out to know which cells already ran"
        ));
    }

    // checkpoint/resume: cells already recorded in --out are skipped, so
    // an interrupted CI shard re-launches from where it stopped
    let mut done: HashSet<(u64, String, String, String)> = HashSet::new();
    if resume {
        if let Some(path) = &out_path {
            if let Ok(text) = std::fs::read_to_string(path) {
                done.extend(text.lines().filter_map(vopr_line_key));
            }
        }
    }
    let mut out_file = match &out_path {
        Some(path) => Some(
            std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)?,
        ),
        None => None,
    };

    let spec = JobSpec::scaled(size, workers);
    let size_arg = flags
        .get("size")
        .cloned()
        .unwrap_or_else(|| size.to_string());
    let total = (seed_end - seed_start) as usize * strategy_names.len() * chaos_modes.len();
    eprintln!(
        "vopr: {workload} workload, seeds [{seed_start}, {seed_end}) x \
         {:?} x {:?} on {workers} workers, {} per run ({total} cells)",
        strategy_names,
        chaos_modes,
        human_bytes(size),
    );

    // per-strategy unfaulted reference digest, computed lazily on the
    // sweep's first seed: every cell must reproduce these exact bytes
    // (per-epoch digests for the stream workload)
    let mut reference: HashMap<String, Option<(u64, u64)>> = HashMap::new();
    let mut stream_reference: HashMap<String, Option<Vec<(u64, u64)>>> =
        HashMap::new();
    let (mut passed, mut failed, mut skipped) = (0usize, 0usize, 0usize);
    for seed in seed_start..seed_end {
        for strategy in &strategy_names {
            let reference = if workload == "sort" {
                *reference.entry(strategy.clone()).or_insert_with(|| {
                    let r = vopr_run_one(&spec, strategy, "none", seed_start, None);
                    r.errors.is_empty().then_some((r.checksum, r.records))
                })
            } else {
                None
            };
            let stream_reference = if workload == "stream" {
                stream_reference
                    .entry(strategy.clone())
                    .or_insert_with(|| {
                        let (r, digests) = vopr_run_stream(
                            &spec, strategy, "none", seed_start, None,
                        );
                        r.errors.is_empty().then_some(digests)
                    })
                    .clone()
            } else {
                None
            };
            for mode in &chaos_modes {
                let key = (
                    seed,
                    strategy.clone(),
                    mode.clone(),
                    workload.to_string(),
                );
                if done.contains(&key) {
                    skipped += 1;
                    continue;
                }
                let r = match workload {
                    "sort" => {
                        vopr_run_one(&spec, strategy, mode, seed, reference)
                    }
                    _ => {
                        vopr_run_stream(
                            &spec,
                            strategy,
                            mode,
                            seed,
                            stream_reference.as_deref(),
                        )
                        .0
                    }
                };
                let ok = r.errors.is_empty();
                if ok {
                    passed += 1;
                } else {
                    failed += 1;
                    for err in &r.errors {
                        eprintln!(
                            "vopr FAIL seed={seed} strategy={strategy} \
                             chaos={mode}: {err}"
                        );
                    }
                    eprintln!(
                        "repro: exoshuffle vopr --workload {workload} \
                         --seed-start {seed} --seed-end {} \
                         --strategies {strategy} --chaos {mode} \
                         --workers {workers} --size {size_arg}",
                        seed + 1
                    );
                }
                let error_json = if ok {
                    "null".to_string()
                } else {
                    format!("\"{}\"", json_escape(&r.errors.join("; ")))
                };
                let line = format!(
                    "{{\"seed\":{seed},\"strategy\":\"{strategy}\",\
                     \"chaos\":\"{mode}\",\"workload\":\"{workload}\",\
                     \"workers\":{workers},\
                     \"ok\":{ok},\"checksum\":\"{:#x}\",\
                     \"records\":{},\"virtual_secs\":{:.6},\
                     \"tasks\":{},\"retries\":{},\"resubmitted\":{},\
                     \"speculated\":{},\"error\":{error_json}}}",
                    r.checksum,
                    r.records,
                    r.virtual_secs,
                    r.tasks_executed,
                    r.tasks_retried,
                    r.tasks_resubmitted,
                    r.tasks_speculated,
                );
                match &mut out_file {
                    Some(f) => writeln!(f, "{line}")?,
                    None => println!("{line}"),
                }
            }
        }
    }
    eprintln!(
        "vopr: {passed} passed, {failed} failed, {skipped} resumed \
         (of {total})"
    );
    if failed > 0 {
        return Err(anyhow::anyhow!("{failed} vopr cell(s) failed"));
    }
    Ok(())
}

fn cmd_cost(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let get = |k: &str, d: f64| -> anyhow::Result<f64> {
        Ok(flags.get(k).map(|v| v.parse()).transpose()?.unwrap_or(d))
    };
    let profile = RunProfile {
        n_workers: get("workers", 40.0)? as usize,
        job_seconds: get("hours", 1.4939)? * 3600.0,
        reduce_seconds: get("reduce-hours", 0.5194)? * 3600.0,
        data_bytes: 100_000_000_000_000,
        get_requests: get("gets", 6_000_000.0)? as u64,
        put_requests: get("puts", 1_000_000.0)? as u64,
    };
    println!("{}", CostModel::paper().render_table2(&profile));
    Ok(())
}

fn cmd_info(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let dir = flags
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"));
    let manifest = std::fs::read_to_string(dir.join("manifest.json"))?;
    println!("artifact manifest ({}):\n{manifest}", dir.display());
    let t = std::time::Instant::now();
    let _backend = Backend::xla(&dir)?;
    println!("XLA backend loaded+compiled in {:.2}s", t.elapsed().as_secs_f64());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use exoshuffle::distfut::chaos::ChaosTrigger;

    fn args(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_flags_handles_values_and_bare_booleans() {
        let flags =
            parse_flags(&args(&["--size", "2MiB", "--resume", "--workers", "3"])).unwrap();
        assert_eq!(flags.get("size").map(String::as_str), Some("2MiB"));
        assert_eq!(flags.get("resume").map(String::as_str), Some("true"));
        assert_eq!(flags.get("workers").map(String::as_str), Some("3"));
    }

    #[test]
    fn parse_flags_rejects_missing_values_and_bare_words() {
        let err = parse_flags(&args(&["--size"])).unwrap_err();
        assert!(err.contains("needs a value"), "{err}");
        let err = parse_flags(&args(&["oops"])).unwrap_err();
        assert!(err.contains("expected --flag"), "{err}");
    }

    #[test]
    fn chaos_kill_parses_single_and_comma_separated() {
        let plan = parse_chaos_kills("1@10").unwrap();
        assert_eq!(plan.triggers.len(), 1);
        assert!(matches!(
            plan.triggers[0],
            ChaosTrigger {
                after_commits: 10,
                event: ChaosEvent::KillNode(1),
            }
        ));
        let plan = parse_chaos_kills("1@10, 2@40").unwrap();
        assert_eq!(plan.triggers.len(), 2);
        assert!(matches!(plan.triggers[1].event, ChaosEvent::KillNode(2)));
        assert_eq!(plan.triggers[1].after_commits, 40);
    }

    #[test]
    fn chaos_kill_rejects_malformed_input_with_clear_errors() {
        for bad in ["", "1", "@5", "1@", "x@5", "1@x", "1@5@7", "-1@5", "1@-5", "1@10,,2@40"]
        {
            let err = parse_chaos_kills(bad).unwrap_err();
            assert!(
                err.contains("--chaos-kill"),
                "'{bad}' must name the flag in its error, got: {err}"
            );
        }
    }

    #[test]
    fn scale_event_parses_onto_an_existing_plan() {
        let plan = ChaosPlan::new().kill_node(1, 5);
        let plan = parse_scale_events("6@100,2@400", plan).unwrap();
        assert_eq!(plan.triggers.len(), 3);
        assert!(matches!(plan.triggers[1].event, ChaosEvent::ScaleTo(6)));
        assert_eq!(plan.triggers[1].after_commits, 100);
        assert!(matches!(plan.triggers[2].event, ChaosEvent::ScaleTo(2)));
    }

    #[test]
    fn scale_event_rejects_malformed_input_with_clear_errors() {
        for bad in ["", "6", "@100", "6@", "w@100", "6@w", "6@1@2"] {
            let err = parse_scale_events(bad, ChaosPlan::new()).unwrap_err();
            assert!(
                err.contains("--scale-event"),
                "'{bad}' must name the flag in its error, got: {err}"
            );
        }
    }

    #[test]
    fn vopr_chaos_plans_are_seed_deterministic() {
        assert!(vopr_chaos_plan("none", 7, 3).is_none());
        let a = vopr_chaos_plan("kill", 7, 3).unwrap();
        let b = vopr_chaos_plan("kill", 7, 3).unwrap();
        assert_eq!(a.triggers.len(), 1);
        assert_eq!(a.triggers[0].after_commits, b.triggers[0].after_commits);
        let d = vopr_chaos_plan("drain", 7, 3).unwrap();
        assert!(matches!(d.triggers[0].event, ChaosEvent::DrainNode(n) if n < 3));
        assert!(d.triggers[0].after_commits >= 3);
        let s = vopr_chaos_plan("slow", 7, 3).unwrap();
        let t = vopr_chaos_plan("slow", 7, 3).unwrap();
        assert_eq!(s.triggers.len(), 2);
        assert!(
            matches!(s.triggers[0].event, ChaosEvent::SlowNode(n, f) if n < 3 && f >= 1.0)
        );
        assert!(s.triggers[0].after_commits >= 3);
        assert!(matches!(s.triggers[1].event, ChaosEvent::S3Latency(_)));
        assert_eq!(s.triggers[0].after_commits, t.triggers[0].after_commits);
        assert_eq!(s.triggers[0].event, t.triggers[0].event);
    }

    #[test]
    fn chaos_slow_parses_node_commits_factor() {
        let plan = parse_chaos_slow("1@10:8", ChaosPlan::new()).unwrap();
        assert_eq!(plan.triggers.len(), 1);
        assert!(matches!(
            plan.triggers[0],
            ChaosTrigger {
                after_commits: 10,
                event: ChaosEvent::SlowNode(1, f),
            } if f == 8.0
        ));
        let plan =
            parse_chaos_slow("1@10:8, 2@40:1.5", ChaosPlan::new()).unwrap();
        assert_eq!(plan.triggers.len(), 2);
        assert!(matches!(
            plan.triggers[1].event,
            ChaosEvent::SlowNode(2, f) if f == 1.5
        ));
    }

    #[test]
    fn chaos_slow_rejects_malformed_input_with_clear_errors() {
        for bad in
            ["", "1", "1@10", "@10:8", "1@:8", "1@10:", "x@10:8", "1@x:8",
             "1@10:x", "1@10:0.5", "1@10:nan", "1@10:8,,2@40:4"]
        {
            let err = parse_chaos_slow(bad, ChaosPlan::new()).unwrap_err();
            assert!(
                err.contains("--chaos-slow"),
                "'{bad}' must name the flag in its error, got: {err}"
            );
        }
    }

    #[test]
    fn chaos_s3_latency_parses_and_rejects() {
        let plan =
            parse_chaos_s3_latency("50@10, 20@40", ChaosPlan::new()).unwrap();
        assert_eq!(plan.triggers.len(), 2);
        assert!(matches!(
            plan.triggers[0],
            ChaosTrigger {
                after_commits: 10,
                event: ChaosEvent::S3Latency(50),
            }
        ));
        for bad in ["", "50", "@10", "50@", "x@10", "50@x", "50@10@2"] {
            let err =
                parse_chaos_s3_latency(bad, ChaosPlan::new()).unwrap_err();
            assert!(
                err.contains("--chaos-s3-latency"),
                "'{bad}' must name the flag in its error, got: {err}"
            );
        }
    }

    #[test]
    fn skew_flag_parses_uniform_and_zipf() {
        assert_eq!(parse_skew("uniform").unwrap(), Skew::Uniform);
        assert!(matches!(parse_skew("zipf:1.2").unwrap(), Skew::Zipf(t) if t == 1.2));
        for bad in ["", "zipf", "zipf:", "zipf:x", "gauss:1.0"] {
            let err = parse_skew(bad).unwrap_err();
            assert!(
                err.contains("--skew"),
                "'{bad}' must name the flag in its error, got: {err}"
            );
        }
    }

    #[test]
    fn speculate_flag_defaults_bare_to_two() {
        assert_eq!(parse_speculate("true").unwrap(), 2.0);
        assert_eq!(parse_speculate("3.5").unwrap(), 3.5);
        let err = parse_speculate("fast").unwrap_err();
        assert!(err.contains("--speculate"), "{err}");
    }

    #[test]
    fn vopr_jsonl_round_trips_its_resume_key() {
        let line = "{\"seed\":42,\"strategy\":\"two-stage-merge\",\
                    \"chaos\":\"kill\",\"workload\":\"stream\",\
                    \"workers\":3,\"ok\":true,\
                    \"checksum\":\"0xabc\",\"records\":100,\
                    \"virtual_secs\":1.5,\"tasks\":10,\"retries\":0,\
                    \"resubmitted\":2,\"error\":null}";
        let key = vopr_line_key(line).unwrap();
        assert_eq!(
            key,
            (
                42,
                "two-stage-merge".into(),
                "kill".into(),
                "stream".into()
            )
        );
        // lines from before the stream workload carry no workload field
        // and must keep resuming as sort cells
        let legacy = "{\"seed\":7,\"strategy\":\"simple\",\
                      \"chaos\":\"none\",\"workers\":3,\"ok\":true}";
        let key = vopr_line_key(legacy).unwrap();
        assert_eq!(key, (7, "simple".into(), "none".into(), "sort".into()));
        assert!(vopr_line_key("not json").is_none());
        assert!(vopr_line_key("{\"seed\":1}").is_none());
    }

    #[test]
    fn json_escape_handles_quotes_and_control_chars() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("line\nbreak\ttab"), "line\\nbreak\\ttab");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }
}
