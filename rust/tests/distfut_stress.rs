//! Stress tests of the distributed-futures runtime: random DAGs, deep
//! chains, wide fan-outs, concurrent submitters, spill churn, and
//! crash-recovery properties (seeded node kills mid-run with
//! byte-identity assertions). These are the paper's §2.5 "for free"
//! guarantees under load.

use std::sync::Arc;

use exoshuffle::distfut::chaos::{ChaosHarness, ChaosPlan};
use exoshuffle::distfut::{
    task_fn, JobId, ObjectRef, Placement, Runtime, RuntimeOptions, TaskSpec,
};
use exoshuffle::util::rng::Xoshiro256;

fn rt(nodes: usize, slots: usize, capacity: u64) -> Arc<Runtime> {
    Runtime::new(RuntimeOptions {
        n_nodes: nodes,
        slots_per_node: slots,
        store_capacity_per_node: capacity,
        spill_root: std::env::temp_dir(),
        ..Default::default()
    })
}

#[test]
fn random_dag_executes_consistently() {
    // Build a random layered DAG whose tasks sum their inputs; verify the
    // sink value against a sequential evaluation.
    let mut rng = Xoshiro256::new(0xDA6);
    let rt = rt(4, 3, u64::MAX);
    let mut layers: Vec<Vec<(exoshuffle::distfut::ObjectRef, u64)>> = vec![];
    // source layer
    let sources: Vec<(exoshuffle::distfut::ObjectRef, u64)> = (0..8u64)
        .map(|i| {
            let v = rng.next_below(100);
            (rt.put((i % 4) as usize, v.to_le_bytes().to_vec()), v)
        })
        .collect();
    layers.push(sources);
    for layer in 1..5 {
        let prev = layers.last().unwrap().clone();
        let mut next = vec![];
        for j in 0..6u64 {
            // pick 1-3 random parents
            let k = 1 + rng.next_below(3) as usize;
            let parents: Vec<_> = (0..k)
                .map(|_| prev[rng.next_below(prev.len() as u64) as usize].clone())
                .collect();
            let expect: u64 = parents.iter().map(|(_, v)| *v).sum();
            let args: Vec<_> = parents.into_iter().map(|(r, _)| r).collect();
            let (outs, _h) = rt.submit(TaskSpec {
                job: JobId::ROOT,
                name: format!("dag-{layer}-{j}"),
                placement: if rng.next_below(2) == 0 {
                    Placement::Any
                } else {
                    Placement::Node(rng.next_below(4) as usize)
                },
                func: task_fn(|ctx| {
                    let sum: u64 = ctx
                        .args
                        .iter()
                        .map(|a| {
                            u64::from_le_bytes(a[..8].try_into().unwrap())
                        })
                        .sum();
                    Ok(vec![sum.to_le_bytes().to_vec()])
                }),
                args,
                num_returns: 1,
                max_retries: 0,
            });
            next.push((outs.into_iter().next().unwrap(), expect));
        }
        layers.push(next);
    }
    for (r, expect) in layers.last().unwrap() {
        let buf = rt.get(r).unwrap();
        assert_eq!(u64::from_le_bytes(buf[..8].try_into().unwrap()), *expect);
    }
}

#[test]
fn deep_chain_resolves() {
    let rt = rt(2, 2, u64::MAX);
    let mut prev = rt.put(0, 0u64.to_le_bytes().to_vec());
    for i in 0..200u64 {
        let (outs, _h) = rt.submit(TaskSpec {
            job: JobId::ROOT,
            name: format!("chain-{i}"),
            placement: Placement::Any,
            func: task_fn(|ctx| {
                let v = u64::from_le_bytes(ctx.args[0][..8].try_into().unwrap());
                Ok(vec![(v + 1).to_le_bytes().to_vec()])
            }),
            args: vec![prev],
            num_returns: 1,
            max_retries: 0,
        });
        prev = outs.into_iter().next().unwrap();
    }
    let buf = rt.get(&prev).unwrap();
    assert_eq!(u64::from_le_bytes(buf[..8].try_into().unwrap()), 200);
}

#[test]
fn wide_fanout_under_spill_pressure() {
    // 64 producers of 64 KiB each against a 128 KiB/node budget: most
    // objects must spill and restore correctly.
    let rt = rt(2, 2, 128 << 10);
    let produced: Vec<_> = (0..64u8)
        .map(|i| {
            let (outs, _h) = rt.submit(TaskSpec {
                job: JobId::ROOT,
                name: format!("spill-{i}"),
                placement: Placement::Any,
                func: task_fn(move |_| Ok(vec![vec![i; 64 << 10]])),
                args: vec![],
                num_returns: 1,
                max_retries: 0,
            });
            outs.into_iter().next().unwrap()
        })
        .collect();
    rt.wait_quiescent();
    let stats = rt.store_stats();
    assert!(stats.spills > 0, "64×64KiB must overflow 2×128KiB: {stats:?}");
    for (i, r) in produced.iter().enumerate() {
        let buf = rt.get(r).unwrap();
        assert_eq!(buf.len(), 64 << 10);
        assert!(buf.iter().all(|&b| b == i as u8), "object {i} corrupted");
    }
    assert!(rt.store_stats().restores > 0);
}

#[test]
fn spill_restore_counters_and_byte_identity() {
    // 16 × 8 KiB puts against a 32 KiB single-node budget: at least 12
    // objects must spill, and every spill/restore must be fully
    // accounted and byte-identical — including restores on the *task
    // argument* path, not just driver gets.
    const OBJ: usize = 8 << 10;
    let rt = rt(1, 2, 32 << 10);
    let refs: Vec<_> = (0..16u8).map(|i| rt.put(0, vec![i; OBJ])).collect();
    let stats = rt.store_stats();
    assert!(stats.spills >= 12, "expected forced spills: {stats:?}");
    assert_eq!(
        stats.spill_bytes,
        stats.spills * OBJ as u64,
        "every spilled object is {OBJ} bytes: {stats:?}"
    );
    assert!(stats.resident_bytes <= 32 << 10, "{stats:?}");

    // restore through a task's argument resolution, verified in-task
    let (_, h) = rt.submit(TaskSpec {
        job: JobId::ROOT,
        name: "verify-args".into(),
        placement: Placement::Node(0),
        func: task_fn(move |ctx| {
            for (i, a) in ctx.args.iter().enumerate() {
                if a.len() != OBJ || !a.iter().all(|&b| b == i as u8) {
                    return Err(format!("object {i} corrupted after restore"));
                }
            }
            Ok(vec![])
        }),
        args: refs.clone(),
        num_returns: 0,
        max_retries: 0,
    });
    h.wait().unwrap();

    // driver-side restores are byte-identical too
    for (i, r) in refs.iter().enumerate() {
        assert_eq!(*rt.get(r).unwrap(), vec![i as u8; OBJ]);
    }
    let stats = rt.store_stats();
    assert!(stats.restores >= stats.spills, "{stats:?}");
    assert_eq!(
        stats.restore_bytes,
        stats.restores * OBJ as u64,
        "every restored object is {OBJ} bytes: {stats:?}"
    );
}

#[test]
fn concurrent_submitters() {
    let rt = rt(3, 2, u64::MAX);
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let rt = rt.clone();
            std::thread::spawn(move || {
                let mut sum_refs = vec![];
                for i in 0..25u64 {
                    let (outs, _h) = rt.submit(TaskSpec {
                        job: JobId::ROOT,
                        name: format!("t{t}-{i}"),
                        placement: Placement::Any,
                        func: task_fn(move |_| {
                            Ok(vec![(t * 1000 + i).to_le_bytes().to_vec()])
                        }),
                        args: vec![],
                        num_returns: 1,
                        max_retries: 0,
                    });
                    sum_refs.push(outs.into_iter().next().unwrap());
                }
                let mut total = 0u64;
                for r in &sum_refs {
                    total += u64::from_le_bytes(
                        rt.get(r).unwrap()[..8].try_into().unwrap(),
                    );
                }
                total
            })
        })
        .collect();
    let mut grand = 0u64;
    for h in handles {
        grand += h.join().unwrap();
    }
    let expect: u64 = (0..4u64)
        .map(|t| (0..25).map(|i| t * 1000 + i).sum::<u64>())
        .sum();
    assert_eq!(grand, expect);
}

#[test]
fn failure_cascades_to_dependents() {
    let rt = rt(1, 1, u64::MAX);
    let (outs, h1) = rt.submit(TaskSpec {
        job: JobId::ROOT,
        name: "doomed".into(),
        placement: Placement::Any,
        func: task_fn(|_| Err("nope".into())),
        args: vec![],
        num_returns: 1,
        max_retries: 1,
    });
    let (_, h2) = rt.submit(TaskSpec {
        job: JobId::ROOT,
        name: "dependent".into(),
        placement: Placement::Any,
        func: task_fn(|_| Ok(vec![])),
        args: vec![outs.into_iter().next().unwrap()],
        num_returns: 0,
        max_retries: 0,
    });
    assert!(h1.wait().is_err());
    let err = h2.wait().unwrap_err().to_string();
    assert!(err.contains("released"), "dependent should observe poisoned arg: {err}");
}

/// A deterministic layered DAG built entirely from tasks (sources too, so
/// every object has lineage and any node may die). Returns the sink refs
/// with their expected values; all intermediate refs are held by `keep`
/// so lost objects always have live observers.
fn sum_dag(
    rt: &Arc<Runtime>,
    nodes: usize,
    keep: &mut Vec<ObjectRef>,
) -> Vec<(ObjectRef, u64)> {
    let mut layers: Vec<Vec<(ObjectRef, u64)>> = Vec::new();
    let sources: Vec<(ObjectRef, u64)> = (0..8u64)
        .map(|i| {
            let v = 10 + i;
            let (outs, _) = rt.submit(TaskSpec {
                job: JobId::ROOT,
                name: format!("src-{i}"),
                placement: Placement::Node((i as usize) % nodes),
                func: task_fn(move |_| Ok(vec![v.to_le_bytes().to_vec()])),
                args: vec![],
                num_returns: 1,
                max_retries: 0,
            });
            (outs.into_iter().next().unwrap(), v)
        })
        .collect();
    layers.push(sources);
    for layer in 1..4 {
        let prev = layers.last().unwrap().clone();
        let mut next = Vec::new();
        for j in 0..6usize {
            // fixed fan-in of two, deterministic parent choice
            let parents = [&prev[j % prev.len()], &prev[(j + 3) % prev.len()]];
            let expect: u64 = parents.iter().map(|(_, v)| *v).sum();
            let args: Vec<ObjectRef> =
                parents.iter().map(|(r, _)| r.clone()).collect();
            let placement = if j % 2 == 0 {
                Placement::Any
            } else {
                Placement::Node((layer + j) % nodes)
            };
            let (outs, _) = rt.submit(TaskSpec {
                job: JobId::ROOT,
                name: format!("dag-{layer}-{j}"),
                placement,
                func: task_fn(|ctx| {
                    let sum: u64 = ctx
                        .args
                        .iter()
                        .map(|a| u64::from_le_bytes(a[..8].try_into().unwrap()))
                        .sum();
                    Ok(vec![sum.to_le_bytes().to_vec()])
                }),
                args,
                num_returns: 1,
                max_retries: 0,
            });
            next.push((outs.into_iter().next().unwrap(), expect));
        }
        layers.push(next);
    }
    for layer in &layers {
        for (r, _) in layer {
            keep.push(r.clone());
        }
    }
    layers.pop().unwrap()
}

#[test]
fn killing_each_node_in_turn_preserves_dag_results() {
    // crash-recovery property: for every victim index, a seeded mid-run
    // kill leaves the DAG's sink values identical to the no-fault run
    // (the expectations double as the byte-identity oracle)
    for victim in 0..3usize {
        let rt = rt(3, 2, u64::MAX);
        let harness =
            ChaosHarness::arm(&rt, ChaosPlan::new().kill_node(victim, 4));
        let mut keep = Vec::new();
        let sinks = sum_dag(&rt, 3, &mut keep);
        for (i, (r, expect)) in sinks.iter().enumerate() {
            let buf = rt.get(r).unwrap();
            assert_eq!(
                u64::from_le_bytes(buf[..8].try_into().unwrap()),
                *expect,
                "victim {victim}, sink {i}"
            );
        }
        assert_eq!(harness.fired(), 1, "victim {victim}: kill must fire");
        let stats = rt.recovery_stats();
        assert_eq!(stats.nodes_killed, 1, "victim {victim}");
        rt.shutdown();
    }
}

#[test]
fn deep_chain_recovers_through_resurrected_lineage() {
    // only the chain tail is kept alive: recovery must resurrect the
    // released intermediates and re-execute the whole chain in order
    let rt = rt(2, 2, u64::MAX);
    let (outs, _) = rt.submit(TaskSpec {
        job: JobId::ROOT,
        name: "chain-0".into(),
        placement: Placement::Node(0),
        func: task_fn(|_| Ok(vec![1u64.to_le_bytes().to_vec()])),
        args: vec![],
        num_returns: 1,
        max_retries: 0,
    });
    let mut prev = outs.into_iter().next().unwrap();
    for i in 1..8u64 {
        let (outs, _) = rt.submit(TaskSpec {
            job: JobId::ROOT,
            name: format!("chain-{i}"),
            placement: Placement::Node(0),
            func: task_fn(|ctx| {
                let v = u64::from_le_bytes(ctx.args[0][..8].try_into().unwrap());
                Ok(vec![(v + 1).to_le_bytes().to_vec()])
            }),
            args: vec![prev],
            num_returns: 1,
            max_retries: 0,
        });
        prev = outs.into_iter().next().unwrap();
    }
    rt.wait_quiescent();
    let report = rt.kill_node(0).unwrap();
    // intermediates were released: only the tail was resident, and the
    // whole chain must come back as resubmissions
    assert_eq!(report.objects_lost, 1, "{report:?}");
    assert_eq!(report.tasks_resubmitted, 8, "{report:?}");
    assert_eq!(report.objects_unrecoverable, 0, "{report:?}");
    let buf = rt.get(&prev).unwrap();
    assert_eq!(u64::from_le_bytes(buf[..8].try_into().unwrap()), 8);
}

#[test]
fn truncated_lineage_surfaces_the_bounded_reconstruction_error() {
    // same chain, but the depth cap is below the chain length: the lost
    // tail must poison with a clear depth error instead of re-executing
    // (or hanging its observer)
    let rt = Runtime::new(RuntimeOptions {
        n_nodes: 2,
        slots_per_node: 2,
        max_reconstruction_depth: 3,
        ..Default::default()
    });
    let (outs, _) = rt.submit(TaskSpec {
        job: JobId::ROOT,
        name: "chain-0".into(),
        placement: Placement::Node(0),
        func: task_fn(|_| Ok(vec![1u64.to_le_bytes().to_vec()])),
        args: vec![],
        num_returns: 1,
        max_retries: 0,
    });
    let mut prev = outs.into_iter().next().unwrap();
    for i in 1..8u64 {
        let (outs, _) = rt.submit(TaskSpec {
            job: JobId::ROOT,
            name: format!("chain-{i}"),
            placement: Placement::Node(0),
            func: task_fn(|ctx| {
                let v = u64::from_le_bytes(ctx.args[0][..8].try_into().unwrap());
                Ok(vec![(v + 1).to_le_bytes().to_vec()])
            }),
            args: vec![prev],
            num_returns: 1,
            max_retries: 0,
        });
        prev = outs.into_iter().next().unwrap();
    }
    rt.wait_quiescent();
    let report = rt.kill_node(0).unwrap();
    assert!(report.objects_unrecoverable >= 1, "{report:?}");
    let err = rt.get(&prev).unwrap_err().to_string();
    assert!(err.contains("unrecoverable"), "{err}");
    assert!(err.contains("depth"), "{err}");
    assert!(err.contains("max_reconstruction_depth"), "{err}");
}

#[test]
fn disabled_lineage_poisons_lost_objects_with_a_clear_error() {
    // record_lineage: false models fully truncated lineage — node loss
    // must poison, not hang
    let rt = Runtime::new(RuntimeOptions {
        n_nodes: 2,
        slots_per_node: 1,
        record_lineage: false,
        ..Default::default()
    });
    let (outs, h) = rt.submit(TaskSpec {
        job: JobId::ROOT,
        name: "src".into(),
        placement: Placement::Node(0),
        func: task_fn(|_| Ok(vec![vec![42u8; 8]])),
        args: vec![],
        num_returns: 1,
        max_retries: 0,
    });
    h.wait().unwrap();
    let report = rt.kill_node(0).unwrap();
    assert_eq!(report.tasks_resubmitted, 0);
    assert_eq!(report.objects_unrecoverable, 1);
    let err = rt.get(&outs[0]).unwrap_err().to_string();
    assert!(err.contains("unrecoverable"), "{err}");
    assert!(err.contains("no lineage"), "{err}");
}

#[test]
fn attempt_counter_visible_to_tasks() {
    let rt = rt(1, 1, u64::MAX);
    let (outs, h) = rt.submit(TaskSpec {
        job: JobId::ROOT,
        name: "count-attempts".into(),
        placement: Placement::Any,
        func: task_fn(|ctx| {
            if ctx.attempt < 3 {
                Err("again".into())
            } else {
                Ok(vec![vec![ctx.attempt as u8]])
            }
        }),
        args: vec![],
        num_returns: 1,
        max_retries: 5,
    });
    h.wait().unwrap();
    assert_eq!(*rt.get(&outs[0]).unwrap(), vec![3u8]);
    assert_eq!(rt.task_counts().1, 3);
}
