//! Integration tests of the control plane + data plane on the native
//! backend: scale sweeps, fault injection, spilling, backpressure, and
//! corruption detection. (XLA-path integration lives in e2e_xla.rs.)

use exoshuffle::coordinator::{run_cloudsort, run_cloudsort_on, JobSpec};
use exoshuffle::runtime::Backend;
use exoshuffle::s3sim::{faults::FaultPlan, S3};
use exoshuffle::sortlib::RECORD_SIZE;

#[test]
fn scale_sweep_validates() {
    for (bytes, workers) in [(1u64 << 20, 1usize), (4 << 20, 3), (16 << 20, 5)] {
        let spec = JobSpec::scaled(bytes, workers);
        let report = run_cloudsort(&spec, Backend::Native).unwrap();
        assert!(
            report.validation.valid,
            "failed at {bytes}B x {workers}w: {:?}",
            report.validation
        );
        assert_eq!(report.validation.summary.records, spec.total_records());
    }
}

#[test]
fn survives_heavy_s3_faults() {
    let spec = JobSpec::scaled(4 << 20, 2);
    let s3 = S3::with_buckets(spec.s3_buckets);
    s3.set_faults(FaultPlan::with_probability(0.15, 42));
    let report = run_cloudsort_on(&spec, Backend::Native, &s3).unwrap();
    assert!(report.validation.valid);
    assert!(report.s3.failed_requests > 0, "faults should have fired");
    assert!(report.task_counts.1 > 0, "failures should cause retries");
}

#[test]
fn unrecoverable_faults_surface_as_errors() {
    let spec = JobSpec::scaled(1 << 20, 2);
    let s3 = S3::with_buckets(spec.s3_buckets);
    // every request fails: retries exhaust and the job must error, not hang
    s3.set_faults(FaultPlan::with_probability(1.0, 7));
    let err = run_cloudsort_on(&spec, Backend::Native, &s3);
    assert!(err.is_err(), "total S3 outage must fail the job");
}

#[test]
fn tiny_store_capacity_forces_spills_but_sorts() {
    let mut spec = JobSpec::scaled(8 << 20, 2);
    spec.store_capacity_per_node = 256 << 10; // 256 KiB per node
    let report = run_cloudsort(&spec, Backend::Native).unwrap();
    assert!(report.validation.valid);
    assert!(
        report.store.spills > 0,
        "a 256 KiB store must spill on an 8 MiB sort"
    );
    assert!(report.store.restores > 0, "spilled blocks must be restored");
}

#[test]
fn backpressure_ablation_both_validate() {
    for backpressure in [true, false] {
        let mut spec = JobSpec::scaled(4 << 20, 2);
        spec.backpressure = backpressure;
        spec.max_buffered_blocks = spec.merge_threshold_blocks;
        let report = run_cloudsort(&spec, Backend::Native).unwrap();
        assert!(report.validation.valid, "backpressure={backpressure}");
    }
}

#[test]
fn output_is_actually_sorted_bytes_on_s3() {
    // read the output partitions back and verify global byte order the
    // hard way (independent of the validation tasks)
    use exoshuffle::coordinator::tasks::{bucket_of, output_key, OUTPUT_SALT};
    let spec = JobSpec::scaled(2 << 20, 2);
    let s3 = S3::with_buckets(spec.s3_buckets);
    let report = run_cloudsort_on(&spec, Backend::Native, &s3).unwrap();
    assert!(report.validation.valid);
    let mut prev: Option<[u8; 10]> = None;
    let mut total = 0u64;
    for r in 0..spec.n_output_partitions {
        let bucket = bucket_of(spec.seed ^ OUTPUT_SALT, r as u64, spec.s3_buckets);
        let buf = s3.get(&bucket, &output_key(r)).unwrap();
        for rec in buf.chunks_exact(RECORD_SIZE) {
            let mut key = [0u8; 10];
            key.copy_from_slice(&rec[..10]);
            if let Some(p) = prev {
                assert!(key >= p, "global order violated at partition {r}");
            }
            prev = Some(key);
            total += 1;
        }
    }
    assert_eq!(total, spec.total_records());
}

#[test]
fn corrupted_output_fails_validation() {
    use exoshuffle::coordinator::tasks::{bucket_of, output_key, OUTPUT_SALT};
    use exoshuffle::sortlib::valsort;
    let spec = JobSpec::scaled(1 << 20, 2);
    let s3 = S3::with_buckets(spec.s3_buckets);
    let report = run_cloudsort_on(&spec, Backend::Native, &s3).unwrap();
    assert!(report.validation.valid);
    // corrupt one byte of one output partition and re-validate manually
    let bucket = bucket_of(spec.seed ^ OUTPUT_SALT, 0, spec.s3_buckets);
    let key = output_key(0);
    let mut buf = (*s3.get(&bucket, &key).unwrap()).clone();
    buf[57] ^= 0xFF;
    let summary = valsort::validate_partition(&buf);
    assert_ne!(
        summary.checksum,
        valsort::validate_partition(&s3.get(&bucket, &key).unwrap()).checksum,
        "corruption must change the checksum"
    );
}

#[test]
fn deterministic_given_seed() {
    let spec = JobSpec::scaled(2 << 20, 2);
    let a = run_cloudsort(&spec, Backend::Native).unwrap();
    let b = run_cloudsort(&spec, Backend::Native).unwrap();
    assert_eq!(
        a.validation.summary.checksum,
        b.validation.summary.checksum
    );
    assert_eq!(a.s3.get_requests, b.s3.get_requests);
}

#[test]
fn task_events_cover_all_families() {
    let spec = JobSpec::scaled(2 << 20, 2);
    let report = run_cloudsort(&spec, Backend::Native).unwrap();
    for family in ["gen-", "map-", "merge-", "reduce-", "validate-"] {
        assert!(
            report.events.iter().any(|e| e.name.starts_with(family)),
            "no {family} events logged"
        );
    }
    // events are well-formed
    for e in &report.events {
        assert!(e.end >= e.start, "{e:?}");
        assert!(e.node < spec.n_workers());
    }
}
