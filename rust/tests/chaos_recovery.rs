//! Chaos-recovery integration tests (paper §2.5): seeded node kills and
//! object losses injected mid-shuffle, with byte-identity assertions
//! against fault-free runs. This is the ISSUE-3 acceptance suite — run
//! it alone with `cargo test -q --test chaos_recovery`.

use exoshuffle::coordinator::tasks::{bucket_of, output_key, OUTPUT_SALT};
use exoshuffle::prelude::*;
use exoshuffle::shuffle::strategy_by_name;

/// Download every output partition, in order.
fn output_bytes(spec: &JobSpec, s3: &S3) -> Vec<Vec<u8>> {
    (0..spec.n_output_partitions)
        .map(|r| {
            s3.get(
                &bucket_of(spec.seed ^ OUTPUT_SALT, r as u64, spec.s3_buckets),
                &output_key(r),
            )
            .unwrap_or_else(|e| panic!("output partition {r}: {e}"))
            .to_vec()
        })
        .collect()
}

/// The headline acceptance property: with a seeded chaos plan that kills
/// a node mid-shuffle, every strategy completes and produces output
/// byte-identical to its fault-free run.
#[test]
fn all_strategies_byte_identical_under_a_midrun_node_kill() {
    let spec = JobSpec::scaled(4 << 20, 3);
    for name in ["two-stage-merge", "simple", "streaming"] {
        let strategy = strategy_by_name(name).expect("registered");
        let clean_s3 = S3::with_buckets(spec.s3_buckets);
        let clean = ShuffleJob::new(spec.clone())
            .strategy_arc(strategy.clone())
            .on(&clean_s3)
            .run()
            .unwrap();
        assert!(clean.validation.valid, "{name} fault-free run");
        assert_eq!(clean.recovery.nodes_killed, 0);
        assert!(clean.chaos.is_empty());

        // kill node 1 after the 10th commit of the sort: deep inside the
        // map stage (the smallest strategy commits ≥ 72 blocks)
        let chaos_s3 = S3::with_buckets(spec.s3_buckets);
        let chaotic = ShuffleJob::new(spec.clone())
            .strategy_arc(strategy)
            .on(&chaos_s3)
            .chaos(ChaosPlan::new().kill_node(1, 10))
            .run()
            .unwrap();
        assert!(
            chaotic.validation.valid,
            "{name} under chaos: {:?}",
            chaotic.validation
        );
        assert_eq!(
            chaotic.recovery.nodes_killed, 1,
            "{name}: the kill must have fired: {:?}",
            chaotic.chaos
        );
        assert!(
            chaotic.chaos[0].outcome.contains("killed node 1"),
            "{name}: {:?}",
            chaotic.chaos
        );
        assert_eq!(
            chaotic.validation.summary.checksum,
            clean.validation.summary.checksum,
            "{name}: checksum must match the fault-free run"
        );
        assert_eq!(
            output_bytes(&spec, &clean_s3),
            output_bytes(&spec, &chaos_s3),
            "{name}: every output partition must be byte-identical"
        );
    }
}

/// Multiple failures in one run: a node kill plus a targeted object loss,
/// against the streaming strategy (whole DAG in flight when both strike).
#[test]
fn streaming_survives_a_kill_plus_an_object_loss() {
    let spec = JobSpec::scaled(4 << 20, 4);
    let clean = ShuffleJob::new(spec.clone())
        .strategy(StreamingShuffle)
        .run()
        .unwrap();
    let report = ShuffleJob::new(spec.clone())
        .strategy(StreamingShuffle)
        .chaos(ChaosPlan::new().kill_node(2, 8).lose_object(25))
        .run()
        .unwrap();
    assert!(report.validation.valid, "{:?}", report.validation);
    assert_eq!(report.chaos.len(), 2, "{:?}", report.chaos);
    assert_eq!(report.recovery.nodes_killed, 1);
    assert!(report.recovery.objects_lost >= 1);
    assert_eq!(
        report.validation.summary.checksum,
        clean.validation.summary.checksum
    );
}

/// Seeded plans are a pure function of their inputs, and riding one
/// through a sort yields a valid, checksum-identical result.
#[test]
fn seeded_chaos_plans_are_reproducible_end_to_end() {
    assert_eq!(
        ChaosPlan::seeded_kills(0xC5A0, 3, 1, (5, 30)),
        ChaosPlan::seeded_kills(0xC5A0, 3, 1, (5, 30)),
    );
    let spec = JobSpec::scaled(2 << 20, 3);
    let clean = ShuffleJob::new(spec.clone()).run().unwrap();
    let plan = ChaosPlan::seeded_kills(0xC5A0, spec.n_workers(), 1, (5, 30));
    let report = ShuffleJob::new(spec.clone())
        .chaos(plan.clone())
        .run()
        .unwrap();
    assert!(report.validation.valid);
    assert_eq!(report.recovery.nodes_killed, 1, "{:?}", report.chaos);
    assert_eq!(
        report.validation.summary.checksum,
        clean.validation.summary.checksum
    );
    // same plan, fresh run: same victim (commit interleaving may differ,
    // bytes may not)
    let again = ShuffleJob::new(spec).chaos(plan).run().unwrap();
    assert!(again.validation.valid);
    assert_eq!(
        again.validation.summary.checksum,
        clean.validation.summary.checksum
    );
}

/// A single-worker job cannot lose its only node: the trigger fires, the
/// kill is refused, and the sort still completes.
#[test]
fn last_live_node_kill_is_refused_and_sort_completes() {
    let spec = JobSpec::scaled(1 << 20, 1);
    let report = ShuffleJob::new(spec)
        .chaos(ChaosPlan::new().kill_node(0, 3))
        .run()
        .unwrap();
    assert!(report.validation.valid);
    assert_eq!(report.recovery.nodes_killed, 0);
    assert_eq!(report.chaos.len(), 1);
    assert!(
        report.chaos[0].outcome.contains("skipped"),
        "{:?}",
        report.chaos
    );
}
