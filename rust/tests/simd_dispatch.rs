//! Forced-dispatch matrix for the SIMD kernels (`sortlib::simd`).
//!
//! Every kernel output must be byte-identical no matter which dispatch
//! tier executes it. The properties suite (P10–P13) checks this against
//! the scalar *reference oracle* on random inputs; this suite pins the
//! *dispatch mechanism itself* on a small fixed matrix of adversarial
//! inputs — duplicate-heavy, constant-digit, extreme-key, and empty —
//! capturing the scalar tier's output and replaying every other
//! available tier against it.
//!
//! CI runs this binary twice: once under `EXOSHUFFLE_SIMD=scalar`
//! (fallback leg) and once with auto-detection. `env_override_is_
//! honored` asserts the env contract in whichever leg is active;
//! `with_forced_tier` then walks every tier the host supports, so both
//! legs still cover the full matrix.

use exoshuffle::sortlib::{
    self, gensort, keyed, radix, reference, simd, RECORD_SIZE,
};

/// The fixed adversarial key sets the matrix replays on every tier.
/// Lengths straddle the vector widths (0, sub-lane, full blocks + tail).
fn adversarial_key_sets() -> Vec<(&'static str, Vec<u64>)> {
    // duplicate-heavy: 8 distinct values over 1000 slots
    let dups: Vec<u64> = (0..1000u64).map(|i| (i * 7 + 3) % 8).collect();
    // constant-digit: all high digits zero, low 16 bits vary
    let low: Vec<u64> = (0..777u64).map(|i| i.wrapping_mul(0x9E37) & 0xFFFF).collect();
    // constant-digit: all top digits saturated
    let high: Vec<u64> = (0..777u64)
        .map(|i| i.wrapping_mul(0x9E3779B97F4A7C15) | 0xFFFF_0000_0000_0000)
        .collect();
    // extreme keys with ties at both ends
    let extreme = vec![u64::MAX, 0, u64::MAX, 1, 0, u64::MAX - 1, u64::MAX, 0];
    // sub-vector-width tails
    let tiny = vec![42u64, 42, 7];
    vec![
        ("empty", Vec::new()),
        ("tiny", tiny),
        ("duplicate-heavy", dups),
        ("constant-low-digits", low),
        ("constant-high-digits", high),
        ("extreme", extreme),
    ]
}

/// Capture `f`'s output with dispatch pinned to `tier`.
fn on<R>(tier: simd::SimdTier, f: impl FnOnce() -> R) -> R {
    simd::with_forced_tier(tier, f)
}

fn non_scalar_tiers() -> Vec<simd::SimdTier> {
    simd::available_tiers()
        .into_iter()
        .filter(|&t| t != simd::SimdTier::Scalar)
        .collect()
}

#[test]
fn sort_pairs_is_tier_invariant() {
    for (name, keys) in adversarial_key_sets() {
        let vals: Vec<u32> = (0..keys.len() as u32).collect();
        let scalar = on(simd::SimdTier::Scalar, || radix::sort_pairs(&keys, &vals));
        for tier in non_scalar_tiers() {
            let got = on(tier, || radix::sort_pairs(&keys, &vals));
            assert_eq!(scalar, got, "sort_pairs[{name}] diverged on {}", tier.name());
        }
    }
}

#[test]
fn partition_offsets_is_tier_invariant() {
    // cuts hit every adversarial shape: below, equal, between, above
    let cuts = [0u64, 1, 3, 7, 0xFFFF, 0xFFFF_0000_0000_0000, u64::MAX];
    for (name, keys) in adversarial_key_sets() {
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        let scalar =
            on(simd::SimdTier::Scalar, || radix::partition_offsets(&sorted, &cuts));
        assert_eq!(scalar, reference::partition_offsets(&sorted, &cuts));
        for tier in non_scalar_tiers() {
            let got = on(tier, || radix::partition_offsets(&sorted, &cuts));
            assert_eq!(
                scalar,
                got,
                "partition_offsets[{name}] diverged on {}",
                tier.name()
            );
        }
    }
}

/// Records whose keys replay the adversarial sets, exercising the BE
/// gather (`extract_partition_keys`), the LE gather + record copies
/// (`from_records`/`keys_of`), and the fused merge walk.
fn records_from_keys(keys: &[u64]) -> Vec<u8> {
    let mut buf = vec![0u8; keys.len() * RECORD_SIZE];
    for (i, (rec, &k)) in
        buf.chunks_exact_mut(RECORD_SIZE).zip(keys).enumerate()
    {
        rec[..8].copy_from_slice(&k.to_be_bytes());
        for (j, b) in rec[8..].iter_mut().enumerate() {
            *b = (i as u8).wrapping_mul(31).wrapping_add(j as u8);
        }
    }
    buf
}

#[test]
fn key_gathers_and_keyed_view_are_tier_invariant() {
    for (name, keys) in adversarial_key_sets() {
        let buf = records_from_keys(&keys);
        let scalar_be =
            on(simd::SimdTier::Scalar, || sortlib::extract_partition_keys(&buf));
        let scalar_keyed = on(simd::SimdTier::Scalar, || keyed::from_records(&buf));
        let scalar_le =
            on(simd::SimdTier::Scalar, || keyed::keys_of(&scalar_keyed));
        assert_eq!(scalar_be, reference::extract_partition_keys(&buf));
        assert_eq!(scalar_le, reference::keys_of_keyed(&scalar_keyed));
        for tier in non_scalar_tiers() {
            let be = on(tier, || sortlib::extract_partition_keys(&buf));
            let kb = on(tier, || keyed::from_records(&buf));
            let le = on(tier, || keyed::keys_of(&kb));
            assert_eq!(scalar_be, be, "BE gather[{name}] diverged on {}", tier.name());
            assert_eq!(scalar_keyed, kb, "from_records[{name}] diverged on {}", tier.name());
            assert_eq!(scalar_le, le, "LE gather[{name}] diverged on {}", tier.name());
        }
    }
}

#[test]
fn fused_merge_is_tier_invariant() {
    // split each adversarial set into 3 sorted runs (some empty)
    let cuts = [2u64, 0xFFFF, u64::MAX];
    for (name, keys) in adversarial_key_sets() {
        let runs: Vec<Vec<u8>> = (0..3)
            .map(|r| {
                let mut part: Vec<u64> =
                    keys.iter().copied().skip(r).step_by(3).collect();
                part.sort_unstable();
                keyed::from_records(&records_from_keys(&part))
            })
            .collect();
        let refs: Vec<&[u8]> = runs.iter().map(|r| r.as_slice()).collect();
        let total: usize = refs.iter().map(|r| keyed::keyed_record_count(r)).sum();
        let mut scalar_out = vec![0u8; total * keyed::KEYED_RECORD_SIZE];
        let scalar_bb = on(simd::SimdTier::Scalar, || {
            keyed::merge_keyed_ranges(&refs, &cuts, &mut scalar_out)
        });
        for tier in non_scalar_tiers() {
            let mut out = vec![0u8; total * keyed::KEYED_RECORD_SIZE];
            let bb = on(tier, || keyed::merge_keyed_ranges(&refs, &cuts, &mut out));
            assert_eq!(scalar_bb, bb, "merge bb[{name}] diverged on {}", tier.name());
            assert_eq!(scalar_out, out, "merge[{name}] diverged on {}", tier.name());
        }
    }
}

#[test]
fn gensort_stream_is_tier_invariant() {
    let specs = [
        gensort::GenSpec { seed: 0, offset: 0, records: 0 }, // empty
        gensort::GenSpec { seed: 1, offset: 0, records: 3 }, // sub-width
        gensort::GenSpec { seed: 0xDEAD_BEEF, offset: 1 << 33, records: 257 },
        gensort::GenSpec { seed: u64::MAX, offset: u64::MAX - 100, records: 64 },
    ];
    for spec in &specs {
        for skew in [sortlib::Skew::Uniform, sortlib::Skew::Zipf(2.0)] {
            let scalar = on(simd::SimdTier::Scalar, || {
                gensort::generate_partition_with(spec, skew)
            });
            assert_eq!(scalar, reference::generate_partition_with(spec, skew));
            for tier in non_scalar_tiers() {
                let got = on(tier, || gensort::generate_partition_with(spec, skew));
                assert_eq!(
                    scalar,
                    got,
                    "gensort[{spec:?} {skew:?}] diverged on {}",
                    tier.name()
                );
            }
        }
    }
}

#[test]
fn env_vocabulary_parses() {
    assert_eq!(simd::SimdTier::from_name("auto"), Some(None));
    for tier in [
        simd::SimdTier::Scalar,
        simd::SimdTier::Sse2,
        simd::SimdTier::Avx2,
        simd::SimdTier::Neon,
    ] {
        assert_eq!(simd::SimdTier::from_name(tier.name()), Some(Some(tier)));
    }
    assert_eq!(simd::SimdTier::from_name("AVX2"), None);
    assert_eq!(simd::SimdTier::from_name(""), None);
}

#[test]
fn env_override_is_honored() {
    // In the CI fallback leg this binary runs under EXOSHUFFLE_SIMD=
    // scalar; assert the detected tier obeys whatever the env says.
    match std::env::var("EXOSHUFFLE_SIMD").ok().as_deref() {
        None | Some("auto") => {
            assert_eq!(simd::detected_tier(), simd::best_available());
        }
        Some(name) => {
            let forced = simd::SimdTier::from_name(name)
                .expect("EXOSHUFFLE_SIMD set to an unknown tier name")
                .expect("\"auto\" handled above");
            assert_eq!(simd::detected_tier(), forced);
        }
    }
}

#[test]
fn available_tiers_are_coherent() {
    let tiers = simd::available_tiers();
    assert_eq!(tiers.first(), Some(&simd::SimdTier::Scalar));
    assert!(tiers.contains(&simd::best_available()));
    for &t in &tiers {
        assert!(simd::tier_available(t), "{} listed but unavailable", t.name());
    }
    // NEON and the x86 tiers are mutually exclusive
    assert!(
        !(tiers.contains(&simd::SimdTier::Neon)
            && tiers.contains(&simd::SimdTier::Sse2))
    );
}
