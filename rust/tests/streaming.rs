//! Streaming-service tests: continuous repartitioning over the batch
//! shuffle machinery (`shuffle::streaming_service`).
//!
//! Acceptance (ISSUE 10): a stream over K epochs is byte-identical per
//! epoch to a batch run of the same shards on both backends, surviving
//! a mid-epoch kill; epochs pipeline (adjacent epochs measurably open
//! at once); `JobReport` carries p50/p95/p99 + SLO violations; sealed
//! epochs leave no store entries behind.

use exoshuffle::prelude::*;

/// A steady source: 20k-record windows (~2 MB epochs) filling in one
/// second, seeded so every test sees the same shard sequence.
fn source() -> IngestSource {
    IngestSource::new(9, 20_000.0, 20_000)
}

#[test]
fn epochs_are_byte_identical_to_batch_on_the_threaded_backend() {
    let report = StreamJob::new(source(), 2)
        .epochs(3)
        .verify_batch(true)
        .run()
        .unwrap();
    assert_eq!(report.watermark, 3, "not every epoch sealed");
    assert!(report.all_valid());
    for ep in &report.epochs {
        assert_eq!(
            ep.batch_identical,
            Some(true),
            "epoch {} diverged from its batch re-run",
            ep.epoch
        );
    }
    // distinct windows carry distinct data — identity is per-epoch, not
    // one dataset sorted thrice
    assert_ne!(report.epochs[0].checksum, report.epochs[1].checksum);
    assert_ne!(report.epochs[1].checksum, report.epochs[2].checksum);
}

#[test]
fn epochs_are_byte_identical_to_batch_on_the_sim_backend() {
    let run = |sim_seed: u64| {
        StreamJob::new(source(), 2)
            .epochs(3)
            .sim_seed(sim_seed)
            .verify_batch(true)
            .run()
            .unwrap()
    };
    let report = run(7);
    assert_eq!(report.watermark, 3);
    assert!(report.all_valid());
    for ep in &report.epochs {
        assert_eq!(
            ep.batch_identical,
            Some(true),
            "epoch {} diverged from its batch re-run",
            ep.epoch
        );
    }
    // output bytes are a pure function of the source, not of event
    // timing: a different sim seed reorders events, same digests
    let digests = |r: &StreamReport| {
        r.epochs
            .iter()
            .map(|e| (e.checksum, e.records))
            .collect::<Vec<_>>()
    };
    assert_eq!(digests(&report), digests(&run(7777)));
}

#[test]
fn adjacent_epochs_pipeline_and_depth_one_serializes() {
    let run = |depth: usize| {
        StreamJob::new(source(), 2)
            .epochs(4)
            .sim_seed(3)
            .pipeline_depth(depth)
            .run()
            .unwrap()
    };
    // depth 2: epoch N+1 admits while epoch N drains, so two epochs are
    // open at once and the overlap clock accumulates
    let piped = run(2);
    assert!(piped.max_open_epochs >= 2, "{piped:?}");
    assert!(
        piped.pipeline_overlap_secs > 0.0,
        "no epoch overlap despite pipeline depth 2: {piped:?}"
    );
    // depth 1 degenerates to serial batch jobs
    let serial = run(1);
    assert_eq!(serial.max_open_epochs, 1);
    assert_eq!(serial.pipeline_overlap_secs, 0.0);
    // pipelining must not change the bytes
    assert_eq!(
        piped
            .epochs
            .iter()
            .map(|e| e.checksum)
            .collect::<Vec<_>>(),
        serial
            .epochs
            .iter()
            .map(|e| e.checksum)
            .collect::<Vec<_>>(),
    );
}

#[test]
fn mid_epoch_kill_recovers_on_the_sim_backend() {
    let report = StreamJob::new(source(), 3)
        .epochs(3)
        .sim_seed(11)
        .chaos(ChaosPlan::new().kill_node(1, 5))
        .chaos_epoch(1)
        .verify_batch(true)
        .run()
        .unwrap();
    assert_eq!(report.watermark, 3, "stream stalled after the kill");
    assert!(report.all_valid());
    for ep in &report.epochs {
        assert_eq!(
            ep.batch_identical,
            Some(true),
            "epoch {} diverged after mid-stream chaos",
            ep.epoch
        );
    }
    // the kill actually fired inside epoch 1, and lineage recovery —
    // scoped to that open epoch — reconstructed the lost objects
    let chaotic = &report.epochs[1].report;
    assert!(!chaotic.chaos.is_empty(), "chaos plan never fired");
    assert!(chaotic.recovery.nodes_killed >= 1);
    assert_eq!(chaotic.recovery.objects_unrecoverable, 0);
}

#[test]
fn mid_epoch_kill_recovers_on_the_threaded_backend() {
    let report = StreamJob::new(source(), 3)
        .epochs(3)
        .chaos(ChaosPlan::new().kill_node(2, 5))
        .chaos_epoch(1)
        .verify_batch(true)
        .run()
        .unwrap();
    assert_eq!(report.watermark, 3, "stream stalled after the kill");
    assert!(report.all_valid());
    for ep in &report.epochs {
        assert_eq!(
            ep.batch_identical,
            Some(true),
            "epoch {} diverged after mid-stream chaos",
            ep.epoch
        );
    }
    assert!(
        !report.epochs[1].report.chaos.is_empty(),
        "chaos plan never fired"
    );
}

#[test]
fn slo_accounting_lands_on_job_reports() {
    // 1 µs objective: the 1 s ingest window alone violates it, so every
    // epoch is a violation
    let tight = StreamJob::new(source(), 2)
        .epochs(3)
        .sim_seed(5)
        .slo_ms(0.001)
        .run()
        .unwrap();
    assert_eq!(tight.latency.n, 3);
    assert_eq!(tight.latency.violations, 3, "{:?}", tight.latency);
    assert!(tight.epochs.iter().all(|e| e.slo_violated));
    assert!((tight.latency.violation_rate() - 1.0).abs() < 1e-12);

    // absurdly generous objective: none violate, and the distribution
    // is stamped on every sealed epoch's JobReport as stats-so-far
    let loose = StreamJob::new(source(), 2)
        .epochs(3)
        .sim_seed(5)
        .slo_ms(1e12)
        .run()
        .unwrap();
    assert_eq!(loose.latency.violations, 0);
    assert!(loose.epochs.iter().all(|e| !e.slo_violated));
    for (i, ep) in loose.epochs.iter().enumerate() {
        let stats = ep.report.latency.as_ref().expect("stamped per epoch");
        assert_eq!(stats.n, i + 1, "epoch {} carries stats-so-far", i);
        assert_eq!(stats.slo_secs, Some(1e9));
    }
    let l = &loose.latency;
    assert!(l.p50_secs <= l.p95_secs && l.p95_secs <= l.p99_secs);
    assert!(l.p99_secs <= l.max_secs);
    // every epoch's latency includes its 1 s ingest window
    assert!(l.p50_secs >= 1.0, "{l:?}");
}

#[test]
fn sealed_epochs_leave_no_store_entries_behind() {
    // drive the stream on a service we own so the runtime stays
    // probe-able after the stream ends
    let epoch_spec = JobSpec::scaled(2_000_000, 2);
    let mut cfg = ServiceConfig::for_spec(&epoch_spec);
    cfg.sim_seed = Some(9);
    let service = JobService::new(cfg);
    let report = StreamJob::new(source(), 2)
        .epochs(4)
        .run_on(&service)
        .unwrap();
    assert_eq!(report.watermark, 4);
    assert!(
        report.all_purged(),
        "an epoch's store entries survived its seal"
    );
    assert_eq!(
        service.runtime().store_live_entries(),
        0,
        "store footprint grew with stream history"
    );
    service.shutdown();
}

#[test]
fn bursts_shrink_windows_and_skew_flows_through() {
    let mut src = source();
    src.burst_every = 2;
    src.burst_factor = 4.0;
    src.skew = Skew::Zipf(1.0);
    let report = StreamJob::new(src, 2)
        .epochs(4)
        .sim_seed(13)
        .run()
        .unwrap();
    assert_eq!(report.watermark, 4);
    assert!(report.all_valid());
    // burst epochs (1, 3) filled at 4x the rate: quarter-length windows
    assert!(
        report.epochs[1].window_secs < report.epochs[0].window_secs / 2.0
    );
    assert!(
        report.epochs[3].window_secs < report.epochs[2].window_secs / 2.0
    );
    // Zipf keys skew the output partition histogram of every epoch
    for ep in &report.epochs {
        assert!(
            ep.report.validation.skew_factor() > 1.5,
            "epoch {} looks uniform under Zipf arrivals",
            ep.epoch
        );
    }
}
