//! Skew- and straggler-robustness integration tests (ISSUE-8 acceptance
//! suite — run alone with `cargo test -q --test skew`):
//!
//! - a Zipf-skewed input sorts byte-identically under uniform and
//!   sampled reducer cuts, on every registered strategy, and sampled
//!   cuts measurably flatten the output-partition histogram;
//! - a speculative run under mid-run SlowNode + S3Latency chaos matches
//!   the unfaulted reference byte-for-byte, with zero duplicate output
//!   commits on the deterministic backend;
//! - the per-partition histogram and skew factor surface degenerate
//!   (duplicate-prefix) inputs instead of silently collapsing.

use exoshuffle::prelude::*;
use exoshuffle::shuffle::{list_strategies, strategy_by_name};
use exoshuffle::sortlib::Skew;

struct RunOutcome {
    report: JobReport,
    duplicate_commits: u64,
    store_leaked: usize,
}

/// Run `spec` under `strategy` on either backend (`sim_seed: None` =
/// threaded), with optional chaos, through the same `JobService` path
/// the CLI and the vopr fuzzer use.
fn run_job(
    spec: &JobSpec,
    strategy: &str,
    sim_seed: Option<u64>,
    chaos: Option<ChaosPlan>,
) -> RunOutcome {
    let mut cfg = ServiceConfig::for_spec(spec);
    cfg.sim_seed = sim_seed;
    let service = JobService::new(cfg);
    let mut job = ShuffleJob::new(spec.clone())
        .strategy_arc(strategy_by_name(strategy).expect("known strategy"))
        .backend(Backend::Native)
        .name(format!("skew-{strategy}"));
    if let Some(plan) = chaos {
        job = job.chaos(plan);
    }
    let report = service
        .submit(job)
        .and_then(|h| h.wait())
        .unwrap_or_else(|e| panic!("{strategy} on {sim_seed:?}: {e:#}"));
    let rt = service.runtime();
    let duplicate_commits = rt.store_stats().duplicate_commits;
    let store_leaked = rt.store_live_entries();
    service.shutdown();
    RunOutcome {
        report,
        duplicate_commits,
        store_leaked,
    }
}

/// The output digest that must agree across cut sources and fault
/// modes: record count and the valsort checksum over the sorted stream.
fn digest(r: &RunOutcome) -> (u64, u64) {
    assert!(
        r.report.validation.valid,
        "invalid output: {:?}",
        r.report.validation
    );
    (
        r.report.validation.summary.records,
        r.report.validation.summary.checksum,
    )
}

/// The partition histogram must be present and account for every
/// record — the skew diagnostic is only trustworthy if it is complete.
fn check_histogram(r: &RunOutcome, spec: &JobSpec) -> f64 {
    let hist = &r.report.validation.partition_records;
    assert_eq!(hist.len(), spec.n_output_partitions, "histogram arity");
    assert_eq!(
        hist.iter().sum::<u64>(),
        r.report.validation.summary.records,
        "histogram must account for every record"
    );
    r.report.validation.skew_factor()
}

/// Headline property: a Zipf-skewed input sorts byte-identically whether
/// the reducer cuts are uniform or sampled, on every strategy — and the
/// sampled cuts demonstrably rebalance the partitions.
#[test]
fn zipf_input_byte_identical_under_uniform_and_sampled_cuts() {
    let mut spec = JobSpec::scaled(2 << 20, 3);
    spec.skew = Skew::Zipf(1.0);
    for strategy in list_strategies() {
        let name = strategy.name();
        let uniform = run_job(&spec, name, None, None);
        let uniform_skew = check_histogram(&uniform, &spec);
        assert!(
            uniform_skew > 2.0,
            "{name}: uniform cuts on a Zipf(1.0) input should be skewed, \
             got factor {uniform_skew:.2}"
        );

        let mut sampled_spec = spec.clone();
        sampled_spec.sample_fraction = 0.5;
        let sampled = run_job(&sampled_spec, name, None, None);
        let sampled_skew = check_histogram(&sampled, &sampled_spec);
        assert_eq!(
            digest(&uniform),
            digest(&sampled),
            "{name}: sampled cuts changed the sorted output"
        );
        assert!(
            sampled.report.sampled_keys > 0,
            "{name}: sampling stage did not run"
        );
        assert!(
            sampled_skew < uniform_skew,
            "{name}: sampled cuts must flatten the histogram \
             ({sampled_skew:.2} vs {uniform_skew:.2})"
        );
        assert!(
            sampled_skew < 2.5,
            "{name}: sampled cuts left factor {sampled_skew:.2}"
        );
        assert_eq!(sampled.store_leaked, 0, "{name}: store leak");
    }
}

/// The same property on the deterministic backend: sim runs with
/// sampled cuts reproduce the threaded uniform-cuts bytes exactly.
#[test]
fn sampled_cuts_match_across_backends() {
    let mut spec = JobSpec::scaled(2 << 20, 3);
    spec.skew = Skew::Zipf(1.0);
    let reference = run_job(&spec, "two-stage-merge", None, None);
    let mut sampled_spec = spec.clone();
    sampled_spec.sample_fraction = 0.5;
    let sim = run_job(&sampled_spec, "two-stage-merge", Some(7), None);
    assert_eq!(
        digest(&reference),
        digest(&sim),
        "sim sampled-cuts output diverged from threaded uniform-cuts"
    );
    assert_eq!(sim.store_leaked, 0);
}

/// Speculative re-execution under mid-run SlowNode + degraded-S3 chaos
/// on the deterministic backend: output matches the unfaulted reference
/// byte-for-byte and the race resolves with zero duplicate commits (the
/// losing copy observes the winner's outputs and skips its body).
#[test]
fn speculation_under_slow_node_sim_matches_reference_with_zero_duplicates() {
    let spec = JobSpec::scaled(2 << 20, 3);
    let reference = run_job(&spec, "two-stage-merge", Some(11), None);
    let mut spec_spec = spec.clone();
    spec_spec.speculate = Some(2.0);
    let plan = ChaosPlan::new().slow_node(0, 50.0, 3).s3_latency(5, 6);
    let raced = run_job(&spec_spec, "two-stage-merge", Some(11), Some(plan));
    assert_eq!(
        digest(&reference),
        digest(&raced),
        "speculative run diverged from the unfaulted reference"
    );
    assert_eq!(raced.report.chaos.len(), 2, "{:?}", raced.report.chaos);
    assert!(
        raced.report.chaos[0].outcome.contains("slowed node 0"),
        "{:?}",
        raced.report.chaos
    );
    let s = &raced.report.speculation;
    assert!(
        s.tasks_speculated >= 1,
        "a 50x straggler node must trigger speculation: {s:?}"
    );
    assert_eq!(
        s.speculative_wins + s.original_wins,
        s.tasks_speculated,
        "every race must settle exactly once: {s:?}"
    );
    assert_eq!(
        raced.duplicate_commits, 0,
        "sim races must resolve by body-skip, not store-level dedup"
    );
    assert_eq!(raced.store_leaked, 0);
}

/// The threaded backend under the same chaos: output is byte-identical
/// to the fault-free run on every strategy; any duplicate commit from a
/// genuinely concurrent race is discarded first-commit-wins.
#[test]
fn threaded_speculation_under_slow_node_is_byte_identical() {
    let spec = JobSpec::scaled(1 << 20, 2);
    for strategy in list_strategies() {
        let name = strategy.name();
        let clean = run_job(&spec, name, None, None);
        let mut spec_spec = spec.clone();
        spec_spec.speculate = Some(2.0);
        let plan = ChaosPlan::new().slow_node(1, 3.0, 5);
        let raced = run_job(&spec_spec, name, None, Some(plan));
        assert_eq!(
            digest(&clean),
            digest(&raced),
            "{name}: speculative threaded run diverged"
        );
        let s = &raced.report.speculation;
        assert_eq!(
            s.speculative_wins + s.original_wins,
            s.tasks_speculated,
            "{name}: races must settle exactly once: {s:?}"
        );
        assert_eq!(raced.store_leaked, 0, "{name}: store leak");
    }
}

/// Satellite diagnostic: a duplicate-prefix-heavy input (high theta
/// collapses many records onto equal 8-byte prefixes) used to fold into
/// one range silently; the histogram and skew factor must now expose
/// the degeneracy while the sort still validates.
#[test]
fn duplicate_prefix_input_reports_degenerate_skew() {
    let mut spec = JobSpec::scaled(2 << 20, 3);
    spec.skew = Skew::Zipf(4.0);
    let r = run_job(&spec, "two-stage-merge", None, None);
    let skew = check_histogram(&r, &spec);
    assert!(
        skew > 4.0,
        "Zipf(4.0) under uniform cuts must report a degenerate \
         histogram, got factor {skew:.2}"
    );
    // sampled cuts rescue even the degenerate input (hot-key splitting
    // keeps the cut vector strictly increasing across equal prefixes)
    let mut sampled_spec = spec.clone();
    sampled_spec.sample_fraction = 1.0;
    let sampled = run_job(&sampled_spec, "two-stage-merge", None, None);
    let sampled_skew = check_histogram(&sampled, &sampled_spec);
    assert_eq!(digest(&r), digest(&sampled));
    assert!(
        sampled_skew < skew,
        "sampled cuts must improve on the degenerate histogram \
         ({sampled_skew:.2} vs {skew:.2})"
    );
}
