//! Integration tests over the full three-layer stack: the XLA engine
//! (AOT Pallas kernels via PJRT) cross-checked against the native
//! baseline, and the complete pipeline run through the XLA path.
//!
//! The engine compiles artifacts lazily; tests share one engine (and use
//! small blocks) to keep one-time XLA compilation bounded.
//!
//! Requires the `pjrt` feature (and `make artifacts`); the default build
//! ships only the native backend, so the whole suite is feature-gated.

#![cfg(feature = "pjrt")]

use std::sync::OnceLock;

use exoshuffle::coordinator::{run_cloudsort, JobSpec};
use exoshuffle::runtime::{merge_and_partition, sort_and_partition, Backend};
use exoshuffle::sortlib::reducer_cuts;
use exoshuffle::util::rng::Xoshiro256;

fn xla() -> Backend {
    static ENGINE: OnceLock<Backend> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            Backend::xla(std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").as_path())
                .expect("run `make artifacts` before `cargo test`")
        })
        .clone()
}

#[test]
fn sort_matches_native_across_sizes_and_distributions() {
    let xla = xla();
    let cuts = reducer_cuts(8);
    for (seed, n) in [(1u64, 1usize), (2, 100), (3, 256), (4, 1000), (5, 4096)] {
        let mut rng = Xoshiro256::new(seed);
        let mut keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        // sprinkle duplicates and extremes
        if n >= 100 {
            keys[0] = 0;
            keys[1] = u64::MAX;
            keys[2] = u64::MAX;
            keys[3] = keys[4];
        }
        let a = sort_and_partition(&xla, &keys, &cuts).unwrap();
        let b = sort_and_partition(&Backend::Native, &keys, &cuts).unwrap();
        assert_eq!(a.keys, b.keys, "keys n={n}");
        assert_eq!(a.perm, b.perm, "perm n={n}");
        assert_eq!(a.offs, b.offs, "offs n={n}");
    }
}

#[test]
fn merge_matches_native_across_shapes() {
    let xla = xla();
    let cuts = reducer_cuts(5);
    for (seed, runs, len) in [(10u64, 2usize, 50usize), (11, 8, 32), (12, 5, 333), (13, 17, 100)]
    {
        let mut rng = Xoshiro256::new(seed);
        let data: Vec<Vec<u64>> = (0..runs)
            .map(|i| {
                let l = if i % 3 == 0 { len / 2 } else { len }; // ragged
                let mut v: Vec<u64> = (0..l).map(|_| rng.next_u64()).collect();
                v.sort_unstable();
                v
            })
            .collect();
        let refs: Vec<&[u64]> = data.iter().map(|d| d.as_slice()).collect();
        let a = merge_and_partition(&xla, &refs, &cuts).unwrap();
        let b = merge_and_partition(&Backend::Native, &refs, &cuts).unwrap();
        assert_eq!(a.keys, b.keys, "keys r={runs} l={len}");
        assert_eq!(a.perm, b.perm, "perm r={runs} l={len}");
        assert_eq!(a.offs, b.offs, "offs r={runs} l={len}");
    }
}

#[test]
fn merge_with_empty_and_single_runs() {
    let xla = xla();
    let empty: Vec<u64> = vec![];
    let single = vec![5u64, 6, 7];
    let a = merge_and_partition(&xla, &[&empty, &single, &empty], &[6]).unwrap();
    assert_eq!(a.keys, vec![5, 6, 7]);
    assert_eq!(a.offs, vec![1]);
}

#[test]
fn full_pipeline_through_xla_kernels() {
    // the E2E composition proof at test scale: every map/merge/reduce
    // task executes AOT-compiled Pallas kernels through PJRT
    let mut spec = JobSpec::scaled(4 << 20, 2);
    spec.seed = 2024;
    let report = run_cloudsort(&spec, xla()).unwrap();
    assert!(report.validation.valid, "{:?}", report.validation);
    assert_eq!(report.validation.summary.records, spec.total_records());
    // kernel engine actually executed
    if let Backend::Xla(engine) = xla() {
        assert!(engine.call_count() > 0, "XLA kernels were never invoked");
    }
}

#[test]
fn xla_and_native_runs_produce_identical_output_checksums() {
    let mut spec = JobSpec::scaled(2 << 20, 2);
    spec.seed = 777;
    let a = run_cloudsort(&spec, xla()).unwrap();
    let b = run_cloudsort(&spec, Backend::Native).unwrap();
    assert_eq!(
        a.validation.summary.checksum,
        b.validation.summary.checksum
    );
    assert_eq!(a.validation.summary.records, b.validation.summary.records);
    assert!(a.validation.valid && b.validation.valid);
}
