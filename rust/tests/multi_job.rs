//! Multi-tenant runtime tests: many concurrent `ShuffleJob`s on one
//! shared `JobService`, with fair-share scheduling and per-job
//! isolation.
//!
//! Acceptance (ISSUE 4): two jobs submitted concurrently both complete
//! with output byte-identical to their sequential runs, and the fairness
//! summary shows neither job held < 25% of task slots while both were
//! runnable.

use exoshuffle::metrics::fairness_summary;
use exoshuffle::prelude::*;
use exoshuffle::shuffle::{
    ShuffleContext, ShuffleOutcome, ShuffleStrategy,
};

/// Solo (sequential) run of a spec+strategy, for byte-identity baselines.
fn solo_checksum(spec: &JobSpec) -> (u64, u64) {
    let report = ShuffleJob::new(spec.clone()).run().unwrap();
    assert!(report.validation.valid, "{:?}", report.validation);
    (
        report.validation.summary.records,
        report.validation.summary.checksum,
    )
}

#[test]
fn two_concurrent_jobs_match_solo_runs_and_share_slots_fairly() {
    // two equal-weight jobs over distinct datasets (different seeds)
    let mut spec_a = JobSpec::scaled(4 << 20, 2);
    spec_a.seed = 101;
    let mut spec_b = JobSpec::scaled(4 << 20, 2);
    spec_b.seed = 202;
    let (solo_a, solo_b) = (solo_checksum(&spec_a), solo_checksum(&spec_b));
    assert_ne!(solo_a.1, solo_b.1, "distinct datasets expected");

    // few slots → real slot contention, so the fairness numbers measure
    // the scheduler rather than an idle cluster
    let mut cfg = ServiceConfig::for_spec(&spec_a);
    cfg.slots_per_node = 2;
    let service = JobService::new(cfg);
    let ha = ShuffleJob::new(spec_a)
        .name("tenant-a")
        .submit(&service)
        .unwrap();
    let hb = ShuffleJob::new(spec_b)
        .name("tenant-b")
        .submit(&service)
        .unwrap();
    let (ra, rb) = (ha.wait().unwrap(), hb.wait().unwrap());
    assert!(ra.validation.valid && rb.validation.valid);

    // byte identity vs the sequential runs (records + gensort checksum
    // is the valsort identity the paper's §3.2 validation checks)
    assert_eq!(
        (ra.validation.summary.records, ra.validation.summary.checksum),
        solo_a,
        "tenant-a output diverged from its solo run"
    );
    assert_eq!(
        (rb.validation.summary.records, rb.validation.summary.checksum),
        solo_b,
        "tenant-b output diverged from its solo run"
    );

    // fairness: neither equal-weight job held < 25% of the task slots
    // while both were runnable
    let fairness = service.fairness();
    assert_eq!(fairness.per_job.len(), 2, "{fairness:?}");
    assert!(
        fairness.window.1 > fairness.window.0,
        "jobs never overlapped: {fairness:?}"
    );
    assert!(
        fairness.min_share() >= 0.25,
        "a tenant was starved: {fairness:?}"
    );
    service.shutdown();
}

#[test]
fn mixed_strategy_jobs_run_concurrently_and_match_solo() {
    // one job per strategy, all concurrent on one runtime, each
    // byte-identical to its solo run
    let strategies: Vec<(&str, std::sync::Arc<dyn ShuffleStrategy>)> = vec![
        ("two-stage-merge", std::sync::Arc::new(TwoStageMerge)),
        ("simple", std::sync::Arc::new(SimpleShuffle)),
        ("streaming", std::sync::Arc::new(StreamingShuffle)),
    ];
    let mut specs = Vec::new();
    for (i, _) in strategies.iter().enumerate() {
        let mut spec = JobSpec::scaled(2 << 20, 2);
        spec.seed = 1000 + i as u64;
        specs.push(spec);
    }
    let solos: Vec<(u64, u64)> = specs.iter().map(solo_checksum).collect();

    let service = JobService::new(ServiceConfig::for_spec(&specs[0]));
    let handles: Vec<JobHandle> = strategies
        .iter()
        .zip(&specs)
        .map(|((name, strategy), spec)| {
            ShuffleJob::new(spec.clone())
                .strategy_arc(strategy.clone())
                .name(*name)
                .submit(&service)
                .unwrap()
        })
        .collect();
    for (h, solo) in handles.iter().zip(&solos) {
        let report = h.wait().unwrap();
        assert!(report.validation.valid, "{}: {:?}", h.name(), report.validation);
        assert_eq!(
            (
                report.validation.summary.records,
                report.validation.summary.checksum
            ),
            *solo,
            "{} diverged from its solo run",
            h.name()
        );
    }
    service.shutdown();
}

/// Max number of this job's attempts executing at once, from the event
/// log (sweep over start/end points; ends processed before starts, so
/// back-to-back attempts on one slot don't double-count).
fn max_concurrency(report: &JobReport) -> usize {
    let mut points: Vec<(f64, i32)> = Vec::new();
    for e in &report.events {
        if e.end > e.start {
            points.push((e.start, 1));
            points.push((e.end, -1));
        }
    }
    points.sort_by(|a, b| {
        a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
    });
    let (mut cur, mut peak) = (0i32, 0i32);
    for (_, d) in points {
        cur += d;
        peak = peak.max(cur);
    }
    peak.max(0) as usize
}

#[test]
fn quota_capped_job_never_exceeds_its_in_flight_budget() {
    let spec = JobSpec::scaled(2 << 20, 2);
    let service = JobService::new(ServiceConfig::for_spec(&spec));
    let cap = 2usize;
    let h = ShuffleJob::new(spec)
        .strategy(SimpleShuffle)
        .name("capped")
        .max_in_flight(cap)
        .submit(&service)
        .unwrap();
    let report = h.wait().unwrap();
    assert!(report.validation.valid);
    let peak = max_concurrency(&report);
    assert!(peak >= 1, "job ran no tasks?");
    assert!(
        peak <= cap,
        "quota violated: {peak} concurrent tasks, budget {cap}"
    );
    service.shutdown();
}

#[test]
fn tiny_job_finishes_while_a_much_larger_job_streams() {
    // the starvation test: a 16x job floods the runtime first; the tiny
    // job submitted after it must still finish far earlier (fair-share
    // dequeue — under plain FIFO its tasks would queue behind the flood)
    let mut big = JobSpec::scaled(32 << 20, 2);
    big.seed = 7;
    let mut tiny = JobSpec::scaled(2 << 20, 2);
    tiny.seed = 8;
    let mut cfg = ServiceConfig::for_spec(&big);
    cfg.slots_per_node = 2; // scarce slots: FIFO would starve the tiny job
    let service = JobService::new(cfg);
    let hb = ShuffleJob::new(big).name("big").submit(&service).unwrap();
    let ht = ShuffleJob::new(tiny).name("tiny").submit(&service).unwrap();
    let rt = ht.wait().unwrap();
    let rb = hb.wait().unwrap();
    assert!(rt.validation.valid && rb.validation.valid);
    // both event logs share the runtime clock: the tiny job's last task
    // must end before the big job's last task
    let end = |r: &JobReport| {
        r.events.iter().map(|e| e.end).fold(0.0f64, f64::max)
    };
    assert!(
        end(&rt) < end(&rb),
        "tiny finished at {:.3}s, big at {:.3}s — starvation?",
        end(&rt),
        end(&rb)
    );
    service.shutdown();
}

#[test]
fn weighted_job_receives_a_larger_slot_share() {
    let mut spec_a = JobSpec::scaled(4 << 20, 2);
    spec_a.seed = 31;
    let mut spec_b = JobSpec::scaled(4 << 20, 2);
    spec_b.seed = 32;
    let mut cfg = ServiceConfig::for_spec(&spec_a);
    cfg.slots_per_node = 2; // contended slots: weights decide shares
    let service = JobService::new(cfg);
    let heavy = ShuffleJob::new(spec_a)
        .name("heavy")
        .priority(4.0)
        .submit(&service)
        .unwrap();
    let light = ShuffleJob::new(spec_b)
        .name("light")
        .priority(1.0)
        .submit(&service)
        .unwrap();
    let (rh, rl) = (heavy.wait().unwrap(), light.wait().unwrap());
    assert!(rh.validation.valid && rl.validation.valid);
    let events: Vec<_> = rh
        .events
        .iter()
        .chain(rl.events.iter())
        .cloned()
        .collect();
    let fairness = fairness_summary(&events);
    if fairness.window.1 > fairness.window.0 {
        // stride weights 4:1 → the heavy job should hold at least its
        // equal share while contended (strict 80% is timing-sensitive;
        // ≥ 50% already separates weighted from round-robin)
        assert!(
            fairness.share_of(heavy.id()) >= 0.5,
            "weight-4 job under-served: {fairness:?}"
        );
    }
    service.shutdown();
}

/// A strategy that always fails mid-stage — exercises the error path.
struct Boom;

impl ShuffleStrategy for Boom {
    fn name(&self) -> &'static str {
        "boom"
    }
    fn describe(&self) -> &'static str {
        "always fails (test strategy)"
    }
    fn stage_names(&self) -> &'static [&'static str] {
        &["boom"]
    }
    fn warmup(&self, _: &JobSpec, _: &Backend) -> anyhow::Result<()> {
        Ok(())
    }
    fn run_stages(&self, _: &ShuffleContext) -> anyhow::Result<ShuffleOutcome> {
        Err(anyhow::anyhow!("synthetic stage failure"))
    }
}

#[test]
fn failed_job_tears_down_cleanly_and_the_service_keeps_serving() {
    // ShuffleJob::run shuts its throwaway service down on the error path
    let err = ShuffleJob::new(JobSpec::scaled(1 << 20, 2))
        .strategy(Boom)
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("synthetic stage failure"), "{err}");

    // and on a shared service, a failed tenant doesn't poison the rest
    let spec = JobSpec::scaled(1 << 20, 2);
    let service = JobService::new(ServiceConfig::for_spec(&spec));
    let bad = ShuffleJob::new(spec.clone())
        .strategy(Boom)
        .name("bad")
        .submit(&service)
        .unwrap();
    assert!(bad.wait().is_err());
    assert_eq!(bad.status(), JobStatus::Failed);
    // the failed job's records are gone (lineage + events retired)
    assert!(service.runtime().task_events().is_empty());
    let good = ShuffleJob::new(spec).name("good").submit(&service).unwrap();
    let report = good.wait().unwrap();
    assert!(report.validation.valid);
    service.shutdown();
}
