//! Cross-backend equivalence tests for the deterministic simulation
//! runtime (`distfut::sim`).
//!
//! Acceptance: for a fixed spec, every registered shuffle strategy
//! produces output byte-identical (checksum + record count) between the
//! threaded backend and the simulation backend — including under a
//! seeded mid-run kill and under a node drain — and a sim run is exactly
//! reproducible from its seed.

use exoshuffle::prelude::*;
use exoshuffle::shuffle::{list_strategies, strategy_by_name};

struct RunOutcome {
    report: JobReport,
    objects_unrecoverable: u64,
    store_leaked: usize,
}

/// Run `spec` under `strategy` on either backend (`sim_seed: None` =
/// threaded), with optional chaos, through the same `JobService` path
/// the CLI and the vopr fuzzer use.
fn run_job(
    spec: &JobSpec,
    strategy: &str,
    sim_seed: Option<u64>,
    chaos: Option<ChaosPlan>,
) -> RunOutcome {
    let mut cfg = ServiceConfig::for_spec(spec);
    cfg.sim_seed = sim_seed;
    let service = JobService::new(cfg);
    let mut job = ShuffleJob::new(spec.clone())
        .strategy_arc(strategy_by_name(strategy).expect("known strategy"))
        .backend(Backend::Native)
        .name(format!("sim-eq-{strategy}"));
    if let Some(plan) = chaos {
        job = job.chaos(plan);
    }
    let report = service
        .submit(job)
        .and_then(|h| h.wait())
        .unwrap_or_else(|e| panic!("{strategy} on {sim_seed:?}: {e:#}"));
    let rt = service.runtime();
    let objects_unrecoverable = rt.recovery_stats().objects_unrecoverable;
    let store_leaked = rt.store_live_entries();
    service.shutdown();
    RunOutcome {
        report,
        objects_unrecoverable,
        store_leaked,
    }
}

/// The output digest that must agree across backends: record count and
/// the valsort checksum over all output partitions.
fn digest(r: &RunOutcome) -> (u64, u64) {
    assert!(
        r.report.validation.valid,
        "invalid output: {:?}",
        r.report.validation
    );
    (
        r.report.validation.summary.records,
        r.report.validation.summary.checksum,
    )
}

#[test]
fn every_strategy_is_byte_identical_threaded_vs_sim() {
    let spec = JobSpec::scaled(2 << 20, 2);
    for strategy in list_strategies() {
        let name = strategy.name();
        let threaded = run_job(&spec, name, None, None);
        let sim = run_job(&spec, name, Some(7), None);
        assert_eq!(
            digest(&threaded),
            digest(&sim),
            "{name}: sim output diverged from threaded"
        );
        assert_eq!(sim.store_leaked, 0, "{name}: sim leaked store entries");
    }
}

#[test]
fn sim_runs_reproduce_exactly_from_their_seed() {
    let spec = JobSpec::scaled(2 << 20, 2);
    let a = run_job(&spec, "two-stage-merge", Some(42), None);
    let b = run_job(&spec, "two-stage-merge", Some(42), None);
    assert_eq!(digest(&a), digest(&b));
    // exact replay: the whole task log matches, including virtual-time
    // stamps (f64-equal, not approximately)
    assert_eq!(a.report.events, b.report.events);
    assert_eq!(a.report.task_counts, b.report.task_counts);
    assert_eq!(a.report.total_secs.to_bits(), b.report.total_secs.to_bits());
}

#[test]
fn seeded_kill_under_sim_matches_unfaulted_threaded_output() {
    let spec = JobSpec::scaled(2 << 20, 3);
    let reference = run_job(&spec, "two-stage-merge", None, None);
    for seed in [3u64, 11] {
        let plan = ChaosPlan::seeded_kills(seed, spec.n_workers(), 1, (3, 20));
        let killed =
            run_job(&spec, "two-stage-merge", Some(seed), Some(plan));
        assert_eq!(
            digest(&reference),
            digest(&killed),
            "seed {seed}: output diverged after a mid-run kill"
        );
        assert_eq!(
            killed.objects_unrecoverable, 0,
            "seed {seed}: lineage failed to reconstruct lost objects"
        );
        assert_eq!(killed.store_leaked, 0, "seed {seed}: store leak");
    }
}

#[test]
fn drain_under_sim_matches_unfaulted_threaded_output() {
    let spec = JobSpec::scaled(2 << 20, 3);
    let reference = run_job(&spec, "two-stage-merge", None, None);
    let plan = ChaosPlan::new().drain_node(1, 5);
    let drained = run_job(&spec, "two-stage-merge", Some(9), Some(plan));
    assert_eq!(
        digest(&reference),
        digest(&drained),
        "output diverged after a mid-run drain"
    );
    assert_eq!(drained.objects_unrecoverable, 0);
    assert_eq!(drained.store_leaked, 0);
}
