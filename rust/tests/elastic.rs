//! Elasticity × chaos acceptance suite (ISSUE 5): live node joins,
//! graceful drains and kill-then-rejoin sequences injected mid-run, with
//! byte-identity assertions against fixed-fleet runs, plus fair-share
//! re-convergence after a scale-up. Run alone with
//! `cargo test -q --test elastic`.

use std::time::{Duration, Instant};

use exoshuffle::coordinator::tasks::{bucket_of, output_key, OUTPUT_SALT};
use exoshuffle::distfut::{
    task_fn, Placement, Runtime, RuntimeOptions, TaskSpec,
};
use exoshuffle::metrics::fairness_summary;
use exoshuffle::prelude::*;
use exoshuffle::shuffle::strategy_by_name;

/// Download every output partition, in order.
fn output_bytes(spec: &JobSpec, s3: &S3) -> Vec<Vec<u8>> {
    (0..spec.n_output_partitions)
        .map(|r| {
            s3.get(
                &bucket_of(spec.seed ^ OUTPUT_SALT, r as u64, spec.s3_buckets),
                &output_key(r),
            )
            .unwrap_or_else(|e| panic!("output partition {r}: {e}"))
            .to_vec()
        })
        .collect()
}

/// A fault-free fixed-fleet run of `spec` with `strategy`, for the
/// byte-identity baseline.
fn clean_run(spec: &JobSpec, strategy: &str) -> (JobReport, Vec<Vec<u8>>) {
    let s3 = S3::with_buckets(spec.s3_buckets);
    let report = ShuffleJob::new(spec.clone())
        .strategy_arc(strategy_by_name(strategy).expect("registered"))
        .on(&s3)
        .run()
        .unwrap();
    assert!(report.validation.valid, "{strategy} fault-free run");
    (report, output_bytes(spec, &s3))
}

/// Headline acceptance: a node hot-joining mid-shuffle changes nothing
/// about the bytes, for every strategy. The elastic service starts two
/// nodes short of the job's plan and grows under the chaos trigger.
#[test]
fn all_strategies_byte_identical_when_a_node_joins_mid_shuffle() {
    let spec = JobSpec::scaled(4 << 20, 3);
    for name in ["two-stage-merge", "simple", "streaming"] {
        let (clean, clean_bytes) = clean_run(&spec, name);

        let mut cfg = ServiceConfig::for_spec(&spec);
        cfg.n_nodes = 2; // the third worker joins at commit 10
        cfg.max_nodes = 3;
        let service = JobService::new(cfg);
        let s3 = S3::with_buckets(spec.s3_buckets);
        let handle = ShuffleJob::new(spec.clone())
            .strategy_arc(strategy_by_name(name).unwrap())
            .on(&s3)
            .chaos(ChaosPlan::new().add_node(10))
            .name(format!("elastic-{name}"))
            .submit(&service)
            .unwrap();
        let report = handle.wait().unwrap();
        assert!(report.validation.valid, "{name}: {:?}", report.validation);
        assert_eq!(
            report.chaos.len(),
            1,
            "{name}: the join must have fired: {:?}",
            report.chaos
        );
        assert!(
            report.chaos[0].outcome.contains("added node 2"),
            "{name}: {:?}",
            report.chaos
        );
        assert_eq!(service.runtime().live_nodes(), 3);
        assert!(
            report.node_timeline.iter().any(|&(_, n)| n == 3),
            "{name}: node-count timeline must record the join: {:?}",
            report.node_timeline
        );
        assert_eq!(
            report.validation.summary.checksum,
            clean.validation.summary.checksum,
            "{name}: checksum must match the fixed-fleet run"
        );
        assert_eq!(
            output_bytes(&spec, &s3),
            clean_bytes,
            "{name}: every output partition must be byte-identical"
        );
        service.shutdown();
    }
}

/// A graceful drain mid-merge loses nothing: no kill, no lost objects,
/// no lineage re-execution — and the bytes match the fixed-fleet run.
#[test]
fn all_strategies_byte_identical_when_a_node_drains_mid_merge() {
    let spec = JobSpec::scaled(4 << 20, 3);
    for name in ["two-stage-merge", "simple", "streaming"] {
        let (clean, clean_bytes) = clean_run(&spec, name);

        let service = JobService::new(ServiceConfig::for_spec(&spec));
        let s3 = S3::with_buckets(spec.s3_buckets);
        // every strategy commits ≥ 72 map blocks at this scale, so
        // commit 60 lands deep in the shuffle — inside the merge window
        // for the merge-based strategies
        let handle = ShuffleJob::new(spec.clone())
            .strategy_arc(strategy_by_name(name).unwrap())
            .on(&s3)
            .chaos(ChaosPlan::new().drain_node(1, 60))
            .submit(&service)
            .unwrap();
        let report = handle.wait().unwrap();
        assert!(report.validation.valid, "{name}: {:?}", report.validation);
        // drains are asynchronous: wait for the retirement to land
        let rt = service.runtime();
        let deadline = Instant::now() + Duration::from_secs(10);
        while !rt.is_node_dead(1) {
            assert!(
                Instant::now() < deadline,
                "{name}: drain never completed"
            );
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(rt.live_nodes(), 2);
        // graceful: nothing was lost and nothing re-executed as recovery
        assert_eq!(rt.recovery_stats().nodes_killed, 0, "{name}");
        assert_eq!(rt.recovery_stats().objects_lost, 0, "{name}");
        assert_eq!(
            report.validation.summary.checksum,
            clean.validation.summary.checksum,
            "{name}"
        );
        assert_eq!(output_bytes(&spec, &s3), clean_bytes, "{name}");
        service.shutdown();
    }
}

/// Seeded kill-then-rejoin: node 1 dies at commit 10 and a fresh
/// incarnation of the slot joins at commit 30. Reproducible end to end,
/// byte-identical to the fault-free run.
#[test]
fn seeded_kill_then_rejoin_is_reproducible_and_byte_identical() {
    let spec = JobSpec::scaled(4 << 20, 3);
    let plan = ChaosPlan::new().kill_node(1, 10).add_node(30);
    let (clean, clean_bytes) = clean_run(&spec, "two-stage-merge");

    let mut checksums = Vec::new();
    let mut outputs = Vec::new();
    for _ in 0..2 {
        let s3 = S3::with_buckets(spec.s3_buckets);
        let report = ShuffleJob::new(spec.clone())
            .on(&s3)
            .chaos(plan.clone())
            .run()
            .unwrap();
        assert!(report.validation.valid, "{:?}", report.validation);
        assert_eq!(report.recovery.nodes_killed, 1, "{:?}", report.chaos);
        assert_eq!(report.chaos.len(), 2, "{:?}", report.chaos);
        assert!(report.chaos[0].outcome.contains("killed node 1"));
        assert!(
            report.chaos[1].outcome.contains("added node 1"),
            "the rejoin must revive the killed slot: {:?}",
            report.chaos
        );
        // the timeline dips to 2 and returns to 3
        assert!(report.node_timeline.iter().any(|&(_, n)| n == 2));
        assert_eq!(report.node_timeline.last().map(|&(_, n)| n), Some(3));
        checksums.push(report.validation.summary.checksum);
        outputs.push(output_bytes(&spec, &s3));
    }
    assert_eq!(checksums[0], checksums[1], "seeded runs must reproduce");
    assert_eq!(outputs[0], outputs[1]);
    assert_eq!(checksums[0], clean.validation.summary.checksum);
    assert_eq!(outputs[0], clean_bytes);
}

fn sleeper(name: &str, ms: u64) -> TaskSpec {
    TaskSpec {
        job: JobId::ROOT,
        name: name.into(),
        placement: Placement::Any,
        func: task_fn(move |_| {
            std::thread::sleep(Duration::from_millis(ms));
            Ok(vec![])
        }),
        args: vec![],
        num_returns: 0,
        max_retries: 0,
    }
}

/// Two equal-weight jobs squeezed onto one slot stay fair through a
/// scale-up: after the second node joins, both jobs' contended-window
/// shares re-converge to ≥ 25% and the joined node takes queued work.
#[test]
fn fair_shares_reconverge_after_a_scale_up() {
    let rt = Runtime::new(RuntimeOptions {
        n_nodes: 1,
        slots_per_node: 1,
        max_nodes: 2,
        ..Default::default()
    });
    let a = rt.register_job(JobParams::default());
    let b = rt.register_job(JobParams::default());
    let mut handles = Vec::new();
    for i in 0..20 {
        handles.push(rt.submit_for(a, sleeper(&format!("a{i}"), 4)).1);
        handles.push(rt.submit_for(b, sleeper(&format!("b{i}"), 4)).1);
    }
    std::thread::sleep(Duration::from_millis(25)); // contend on one slot
    let node = rt.add_node().unwrap();
    assert_eq!(node, 1);
    assert_eq!(rt.n_nodes(), 2, "provisioned span must grow");
    for h in handles {
        h.wait().unwrap();
    }
    let events = rt.task_events();
    assert!(
        events.iter().any(|e| e.node == 1 && e.ok),
        "the joined node must take rebalanced queue work"
    );
    let summary = fairness_summary(&events);
    assert!(
        summary.share_of(a) >= 0.25 && summary.share_of(b) >= 0.25,
        "shares must re-converge across the scale-up: {summary:?}"
    );
    // the ceiling is enforced once every slot is live
    let err = rt.add_node().unwrap_err().to_string();
    assert!(err.contains("max_nodes"), "{err}");
    rt.shutdown();
}

/// Drain semantics at the runtime level: migration instead of loss, the
/// last-node guard, and slot revival as a fresh incarnation.
#[test]
fn drain_migrates_objects_then_slot_revives_as_a_fresh_node() {
    let rt = Runtime::new(RuntimeOptions {
        n_nodes: 2,
        slots_per_node: 1,
        ..Default::default()
    });
    let (outs, h) = rt.submit(TaskSpec {
        job: JobId::ROOT,
        name: "resident".into(),
        placement: Placement::Node(0),
        func: task_fn(|_| Ok(vec![vec![7u8; 64]])),
        args: vec![],
        num_returns: 1,
        max_retries: 0,
    });
    h.wait().unwrap();
    let report = rt.drain_node(0).unwrap();
    assert_eq!(report.objects_migrated, 1, "{report:?}");
    assert_eq!(report.bytes_migrated, 64);
    assert!(rt.is_node_dead(0));
    assert_eq!(rt.live_nodes(), 1);
    // nothing lost, no recovery machinery engaged, data still readable
    assert_eq!(rt.recovery_stats().objects_lost, 0);
    assert_eq!(rt.recovery_stats().tasks_resubmitted, 0);
    assert_eq!(*rt.get(&outs[0]).unwrap(), vec![7u8; 64]);
    // the last available node refuses to drain
    let err = rt.drain_node(1).unwrap_err().to_string();
    assert!(err.contains("last available"), "{err}");
    // re-adding revives the retired slot; pinned work runs there again
    assert_eq!(rt.add_node().unwrap(), 0);
    assert_eq!(rt.live_nodes(), 2);
    let (_, h) = rt.submit(TaskSpec {
        job: JobId::ROOT,
        name: "after-rejoin".into(),
        placement: Placement::Node(0),
        func: task_fn(|_| Ok(vec![])),
        args: vec![],
        num_returns: 0,
        max_retries: 0,
    });
    h.wait().unwrap();
    let events = rt.task_events();
    assert!(events
        .iter()
        .any(|e| e.name == "after-rejoin" && e.node == 0 && e.ok));
    // membership markers for reports
    assert!(events.iter().any(|e| e.name == "node-drained-0"));
    assert!(events.iter().any(|e| e.name == "node-added-0"));
    rt.shutdown();
}

/// A drain with work queued on the draining node reroutes it (counted
/// on the report) and the job still completes.
#[test]
fn drain_reroutes_queued_work_and_backlog_completes() {
    let rt = Runtime::new(RuntimeOptions {
        n_nodes: 2,
        slots_per_node: 1,
        ..Default::default()
    });
    // one long task occupies node 1 while a pinned backlog queues there
    let (_, busy) = rt.submit(TaskSpec {
        job: JobId::ROOT,
        name: "busy".into(),
        placement: Placement::Node(1),
        func: task_fn(|_| {
            std::thread::sleep(Duration::from_millis(60));
            Ok(vec![])
        }),
        args: vec![],
        num_returns: 0,
        max_retries: 0,
    });
    std::thread::sleep(Duration::from_millis(10));
    let handles: Vec<_> = (0..6)
        .map(|i| {
            rt.submit(TaskSpec {
                job: JobId::ROOT,
                name: format!("queued{i}"),
                placement: Placement::Node(1),
                func: task_fn(|_| Ok(vec![])),
                args: vec![],
                num_returns: 0,
                max_retries: 0,
            })
            .1
        })
        .collect();
    let report = rt.drain_node(1).unwrap();
    assert!(
        report.queue_reroutes >= 1,
        "queued work must reroute: {report:?}"
    );
    busy.wait().unwrap();
    for h in handles {
        h.wait().unwrap();
    }
    // everything ran on the surviving node after the drain began
    assert!(rt
        .task_events()
        .iter()
        .filter(|e| e.name.starts_with("queued"))
        .all(|e| e.node == 0));
    rt.shutdown();
}
