//! Property-based tests (hand-rolled generator loops — proptest is not in
//! the offline crate set; see DESIGN.md). Each property runs against many
//! seeded random cases and shrinking is replaced by printing the seed.
//!
//! Invariants covered:
//!   P1  sort output is a sorted permutation of its input
//!   P2  partition offsets bound every cut correctly (count of keys < cut)
//!   P3  k-way merge == sort of the concatenation
//!   P4  valsort accepts exactly the outputs whose order is correct
//!   P5  gensort is O(1)-addressable: any sub-partition equals the slice
//!       of the full generation
//!   P6  the whole pipeline preserves record multisets (checksum + count)
//!       for arbitrary job geometries

use exoshuffle::coordinator::{run_cloudsort, JobSpec};
use exoshuffle::runtime::{native, Backend};
use exoshuffle::sortlib::{gensort, radix, valsort, RECORD_SIZE};
use exoshuffle::util::rng::Xoshiro256;

const CASES: u64 = 50;

#[test]
fn p1_sort_is_sorted_permutation() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(seed);
        let n = rng.next_below(2000) as usize;
        let keys: Vec<u64> = (0..n)
            .map(|_| {
                // mix uniform with low-cardinality to stress duplicates
                if rng.next_below(4) == 0 {
                    rng.next_below(16)
                } else {
                    rng.next_u64()
                }
            })
            .collect();
        let r = native::sort_and_partition(&keys, &[]);
        assert!(r.keys.windows(2).all(|w| w[0] <= w[1]), "seed {seed}");
        let mut seen = vec![false; n];
        for (i, &p) in r.perm.iter().enumerate() {
            assert!(!seen[p as usize], "seed {seed}: perm not injective");
            seen[p as usize] = true;
            assert_eq!(keys[p as usize], r.keys[i], "seed {seed}");
        }
    }
}

#[test]
fn p2_partition_offsets_bound_cuts() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(1000 + seed);
        let n = rng.next_below(1000) as usize;
        let mut keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        keys.sort_unstable();
        let c = rng.next_below(50) as usize;
        let mut cuts: Vec<u64> = (0..c).map(|_| rng.next_u64()).collect();
        cuts.sort_unstable();
        let offs = radix::partition_offsets(&keys, &cuts);
        for (i, (&cut, &off)) in cuts.iter().zip(&offs).enumerate() {
            let expect = keys.iter().filter(|&&k| k < cut).count() as u32;
            assert_eq!(off, expect, "seed {seed} cut {i}");
        }
        // offsets are monotone
        assert!(offs.windows(2).all(|w| w[0] <= w[1]), "seed {seed}");
    }
}

#[test]
fn p3_merge_equals_sort_of_concat() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(2000 + seed);
        let n_runs = 1 + rng.next_below(10) as usize;
        let runs: Vec<Vec<u64>> = (0..n_runs)
            .map(|_| {
                let l = rng.next_below(300) as usize;
                let mut v: Vec<u64> = (0..l).map(|_| rng.next_u64()).collect();
                v.sort_unstable();
                v
            })
            .collect();
        let refs: Vec<&[u64]> = runs.iter().map(|r| r.as_slice()).collect();
        let merged = native::merge_and_partition(&refs, &[]);
        let concat: Vec<u64> = runs.iter().flatten().copied().collect();
        let sorted = native::sort_and_partition(&concat, &[]);
        assert_eq!(merged.keys, sorted.keys, "seed {seed}");
    }
}

#[test]
fn p4_valsort_accepts_iff_sorted() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(3000 + seed);
        let n = 2 + rng.next_below(200);
        let buf = gensort::generate_partition(&gensort::GenSpec {
            seed,
            offset: 0,
            records: n,
        });
        // unsorted input: should report inversions (overwhelmingly likely
        // for n >= 2 random keys; check and skip the degenerate case)
        let s = valsort::validate_partition(&buf);
        // sort it properly by full 10-byte key
        let mut recs: Vec<&[u8]> = buf.chunks_exact(RECORD_SIZE).collect();
        recs.sort_by_key(|r| {
            let mut k = [0u8; 10];
            k.copy_from_slice(&r[..10]);
            k
        });
        let sorted: Vec<u8> = recs.concat();
        let s2 = valsort::validate_partition(&sorted);
        assert_eq!(s2.unordered, 0, "seed {seed}");
        assert_eq!(s2.checksum, s.checksum, "seed {seed}: checksum must be order-independent");
        assert_eq!(s2.records, n, "seed {seed}");
    }
}

#[test]
fn p5_gensort_random_access() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(4000 + seed);
        let total = 10 + rng.next_below(500);
        let full = gensort::generate_partition(&gensort::GenSpec {
            seed,
            offset: 0,
            records: total,
        });
        let off = rng.next_below(total);
        let len = 1 + rng.next_below(total - off);
        let part = gensort::generate_partition(&gensort::GenSpec {
            seed,
            offset: off,
            records: len,
        });
        let lo = off as usize * RECORD_SIZE;
        let hi = (off + len) as usize * RECORD_SIZE;
        assert_eq!(part, &full[lo..hi], "seed {seed} off {off} len {len}");
    }
}

#[test]
fn p6_pipeline_preserves_multiset_across_geometries() {
    for seed in 0..8 {
        let mut rng = Xoshiro256::new(5000 + seed);
        let workers = 1 + rng.next_below(4) as usize;
        let mib = 1 + rng.next_below(4);
        let mut spec = JobSpec::scaled(mib << 20, workers);
        spec.seed = seed * 13 + 1;
        spec.merge_threshold_blocks = 1 + rng.next_below(8) as usize;
        spec.backpressure = rng.next_below(2) == 0;
        let report = run_cloudsort(&spec, Backend::Native).unwrap();
        assert!(
            report.validation.valid,
            "seed {seed}: {:?} spec {:?}",
            report.validation, spec
        );
        assert_eq!(
            report.validation.summary.records,
            spec.total_records(),
            "seed {seed}"
        );
    }
}
