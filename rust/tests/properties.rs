//! Property-based tests (hand-rolled generator loops — proptest is not in
//! the offline crate set; see DESIGN.md). Each property runs against many
//! seeded random cases and shrinking is replaced by printing the seed.
//!
//! Invariants covered:
//!   P1  sort output is a sorted permutation of its input
//!   P2  partition offsets bound every cut correctly (count of keys < cut)
//!   P3  k-way merge == sort of the concatenation
//!   P4  valsort accepts exactly the outputs whose order is correct
//!   P5  gensort is O(1)-addressable: any sub-partition equals the slice
//!       of the full generation
//!   P6  the whole pipeline preserves record multisets (checksum + count)
//!       for arbitrary job geometries
//!   P7  SoA radix sort_pairs is bit-for-bit the AoS reference it replaced
//!   P8  in-place fix_key_ties is byte- and count-identical to the
//!       allocating reference
//!   P9  the fused keyed merge+gather reproduces the two-pass reference
//!       (merge indices, then gather) for arbitrary run sets and cuts
//!   P10 SIMD radix sort_pairs is bit-for-bit the scalar reference on
//!       every available dispatch tier (forced via sortlib::simd)
//!   P11 SIMD partition_offsets and the strided key gathers (BE records,
//!       LE keyed buffers) match their scalar reference on every tier
//!   P12 the fused keyed merge+gather is byte-identical to the reference
//!       two-pass path on every tier (vector record copies included)
//!   P13 the batched gensort generator (vectorized SplitMix64 stream)
//!       reproduces the frozen per-record reference on every tier, for
//!       uniform and Zipf key distributions

use exoshuffle::coordinator::{run_cloudsort, JobSpec};
use exoshuffle::runtime::{native, Backend};
use exoshuffle::sortlib::{
    self, gensort, keyed, radix, reference, simd, valsort, RECORD_SIZE,
};
use exoshuffle::util::rng::Xoshiro256;

const CASES: u64 = 50;

#[test]
fn p1_sort_is_sorted_permutation() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(seed);
        let n = rng.next_below(2000) as usize;
        let keys: Vec<u64> = (0..n)
            .map(|_| {
                // mix uniform with low-cardinality to stress duplicates
                if rng.next_below(4) == 0 {
                    rng.next_below(16)
                } else {
                    rng.next_u64()
                }
            })
            .collect();
        let r = native::sort_and_partition(&keys, &[]);
        assert!(r.keys.windows(2).all(|w| w[0] <= w[1]), "seed {seed}");
        let mut seen = vec![false; n];
        for (i, &p) in r.perm.iter().enumerate() {
            assert!(!seen[p as usize], "seed {seed}: perm not injective");
            seen[p as usize] = true;
            assert_eq!(keys[p as usize], r.keys[i], "seed {seed}");
        }
    }
}

#[test]
fn p2_partition_offsets_bound_cuts() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(1000 + seed);
        let n = rng.next_below(1000) as usize;
        let mut keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        keys.sort_unstable();
        let c = rng.next_below(50) as usize;
        let mut cuts: Vec<u64> = (0..c).map(|_| rng.next_u64()).collect();
        cuts.sort_unstable();
        let offs = radix::partition_offsets(&keys, &cuts);
        for (i, (&cut, &off)) in cuts.iter().zip(&offs).enumerate() {
            let expect = keys.iter().filter(|&&k| k < cut).count() as u32;
            assert_eq!(off, expect, "seed {seed} cut {i}");
        }
        // offsets are monotone
        assert!(offs.windows(2).all(|w| w[0] <= w[1]), "seed {seed}");
    }
}

#[test]
fn p3_merge_equals_sort_of_concat() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(2000 + seed);
        let n_runs = 1 + rng.next_below(10) as usize;
        let runs: Vec<Vec<u64>> = (0..n_runs)
            .map(|_| {
                let l = rng.next_below(300) as usize;
                let mut v: Vec<u64> = (0..l).map(|_| rng.next_u64()).collect();
                v.sort_unstable();
                v
            })
            .collect();
        let refs: Vec<&[u64]> = runs.iter().map(|r| r.as_slice()).collect();
        let merged = native::merge_and_partition(&refs, &[]);
        let concat: Vec<u64> = runs.iter().flatten().copied().collect();
        let sorted = native::sort_and_partition(&concat, &[]);
        assert_eq!(merged.keys, sorted.keys, "seed {seed}");
    }
}

#[test]
fn p4_valsort_accepts_iff_sorted() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(3000 + seed);
        let n = 2 + rng.next_below(200);
        let buf = gensort::generate_partition(&gensort::GenSpec {
            seed,
            offset: 0,
            records: n,
        });
        // unsorted input: should report inversions (overwhelmingly likely
        // for n >= 2 random keys; check and skip the degenerate case)
        let s = valsort::validate_partition(&buf);
        // sort it properly by full 10-byte key
        let mut recs: Vec<&[u8]> = buf.chunks_exact(RECORD_SIZE).collect();
        recs.sort_by_key(|r| {
            let mut k = [0u8; 10];
            k.copy_from_slice(&r[..10]);
            k
        });
        let sorted: Vec<u8> = recs.concat();
        let s2 = valsort::validate_partition(&sorted);
        assert_eq!(s2.unordered, 0, "seed {seed}");
        assert_eq!(s2.checksum, s.checksum, "seed {seed}: checksum must be order-independent");
        assert_eq!(s2.records, n, "seed {seed}");
    }
}

#[test]
fn p5_gensort_random_access() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(4000 + seed);
        let total = 10 + rng.next_below(500);
        let full = gensort::generate_partition(&gensort::GenSpec {
            seed,
            offset: 0,
            records: total,
        });
        let off = rng.next_below(total);
        let len = 1 + rng.next_below(total - off);
        let part = gensort::generate_partition(&gensort::GenSpec {
            seed,
            offset: off,
            records: len,
        });
        let lo = off as usize * RECORD_SIZE;
        let hi = (off + len) as usize * RECORD_SIZE;
        assert_eq!(part, &full[lo..hi], "seed {seed} off {off} len {len}");
    }
}

#[test]
fn p7_soa_sort_pairs_matches_reference() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(6000 + seed);
        let n = rng.next_below(3000) as usize;
        let mode = rng.next_below(4);
        let keys: Vec<u64> = (0..n)
            .map(|_| match mode {
                // heavy duplicates
                0 => rng.next_below(16),
                // three constant (zero) high digits — exercises pass skipping
                1 => rng.next_u64() & 0xFFFF,
                // constant all-ones top digit
                2 => rng.next_u64() | 0xFFFF_0000_0000_0000,
                _ => rng.next_u64(),
            })
            .collect();
        let vals: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
        assert_eq!(
            radix::sort_pairs(&keys, &vals),
            reference::sort_pairs(&keys, &vals),
            "seed {seed}"
        );
    }
    // explicit edges: empty input, extreme keys with duplicates
    assert_eq!(radix::sort_pairs(&[], &[]), reference::sort_pairs(&[], &[]));
    let ks = [u64::MAX, 0, u64::MAX, 1, 0];
    let vs = [0, 1, 2, 3, 4];
    assert_eq!(radix::sort_pairs(&ks, &vs), reference::sort_pairs(&ks, &vs));
}

#[test]
fn p8_fix_key_ties_matches_reference() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(7000 + seed);
        let n = rng.next_below(300) as usize;
        let n_prefixes = 1 + rng.next_below(20) as usize;
        let prefixes: Vec<[u8; 8]> = (0..n_prefixes)
            .map(|_| rng.next_u64().to_be_bytes())
            .collect();
        let mut buf = vec![0u8; n * RECORD_SIZE];
        for i in 0..n {
            let r = &mut buf[i * RECORD_SIZE..(i + 1) * RECORD_SIZE];
            r[..8].copy_from_slice(
                &prefixes[rng.next_below(n_prefixes as u64) as usize],
            );
            // low-cardinality key tail: some groups tie on the full
            // 10-byte key too (the no-move path)
            r[8] = rng.next_below(4) as u8;
            r[9] = rng.next_below(4) as u8;
            for b in r[10..].iter_mut() {
                *b = rng.next_u64() as u8;
            }
        }
        // group colliding prefixes the way the pipeline does: stable
        // sort by the 8-byte partition key
        let mut recs: Vec<Vec<u8>> =
            buf.chunks_exact(RECORD_SIZE).map(|r| r.to_vec()).collect();
        recs.sort_by_key(|r| sortlib::partition_key(r));
        let sorted: Vec<u8> = recs.concat();
        let mut a = sorted.clone();
        let mut b = sorted;
        let moved_a = sortlib::fix_key_ties(&mut a);
        let moved_b = reference::fix_key_ties(&mut b);
        assert_eq!(a, b, "seed {seed}: bytes diverged");
        assert_eq!(moved_a, moved_b, "seed {seed}: moved counts diverged");
    }
}

#[test]
fn p9_fused_keyed_merge_matches_reference() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(8000 + seed);
        let n_runs = rng.next_below(6) as usize; // includes the 0-run case
        let built: Vec<(Vec<u8>, Vec<u8>)> = (0..n_runs)
            .map(|_| {
                let l = rng.next_below(200) as usize; // includes empty runs
                let mut recs: Vec<Vec<u8>> = (0..l)
                    .map(|_| {
                        let mut r = vec![0u8; RECORD_SIZE];
                        // low-cardinality keys force cross-run duplicates,
                        // stressing the merge tie-break
                        let k = if rng.next_below(2) == 0 {
                            rng.next_below(32)
                        } else {
                            rng.next_u64()
                        };
                        r[..8].copy_from_slice(&k.to_be_bytes());
                        for b in r[8..].iter_mut() {
                            *b = rng.next_u64() as u8;
                        }
                        r
                    })
                    .collect();
                recs.sort_by_key(|r| sortlib::partition_key(r));
                let plain: Vec<u8> = recs.concat();
                let keyed_run = keyed::from_records(&plain);
                (plain, keyed_run)
            })
            .collect();
        let plain: Vec<&[u8]> = built.iter().map(|(p, _)| p.as_slice()).collect();
        let keyed_runs: Vec<&[u8]> =
            built.iter().map(|(_, k)| k.as_slice()).collect();
        let c = rng.next_below(6) as usize;
        let mut cuts: Vec<u64> = (0..c)
            .map(|_| match rng.next_below(8) {
                0 => 0,                  // leading empty range
                1 => u64::MAX,           // (almost) trailing empty range
                2 => rng.next_below(32), // lands inside the duplicate mass
                _ => rng.next_u64(),
            })
            .collect();
        cuts.sort_unstable();
        let total: usize =
            keyed_runs.iter().map(|r| keyed::keyed_record_count(r)).sum();
        let want = reference::merge_then_gather(&plain, &cuts);
        let mut fused = vec![0u8; total * keyed::KEYED_RECORD_SIZE];
        let bb = keyed::merge_keyed_ranges(&keyed_runs, &cuts, &mut fused);
        assert_eq!(bb.len(), cuts.len() + 2, "seed {seed}");
        let got: Vec<Vec<u8>> = bb
            .windows(2)
            .map(|w| keyed::to_records(&fused[w[0]..w[1]]))
            .collect();
        assert_eq!(want, got, "seed {seed}");
    }
}

/// Run `f` once per available SIMD tier with dispatch pinned to it.
/// Includes Scalar always, so every property below self-checks the
/// fallback path even on exotic architectures.
fn for_each_tier(f: impl Fn(simd::SimdTier)) {
    for tier in simd::available_tiers() {
        simd::with_forced_tier(tier, || f(tier));
    }
}

#[test]
fn p10_simd_sort_pairs_matches_reference_on_all_tiers() {
    for_each_tier(|tier| {
        for seed in 0..CASES / 2 {
            let mut rng = Xoshiro256::new(9000 + seed);
            let n = rng.next_below(3000) as usize;
            let mode = rng.next_below(4);
            let keys: Vec<u64> = (0..n)
                .map(|_| match mode {
                    // heavy duplicates
                    0 => rng.next_below(16),
                    // constant (zero) high digits — exercises pass skipping
                    1 => rng.next_u64() & 0xFFFF,
                    // constant all-ones top digit
                    2 => rng.next_u64() | 0xFFFF_0000_0000_0000,
                    _ => rng.next_u64(),
                })
                .collect();
            let vals: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
            assert_eq!(
                radix::sort_pairs(&keys, &vals),
                reference::sort_pairs(&keys, &vals),
                "tier {} seed {seed}",
                tier.name()
            );
        }
        // edges: empty input, extreme keys with duplicates
        assert_eq!(radix::sort_pairs(&[], &[]), reference::sort_pairs(&[], &[]));
        let ks = [u64::MAX, 0, u64::MAX, 1, 0];
        let vs = [0, 1, 2, 3, 4];
        assert_eq!(
            radix::sort_pairs(&ks, &vs),
            reference::sort_pairs(&ks, &vs),
            "tier {}",
            tier.name()
        );
    });
}

#[test]
fn p11_simd_offsets_and_key_gathers_match_reference_on_all_tiers() {
    for_each_tier(|tier| {
        for seed in 0..CASES / 2 {
            let mut rng = Xoshiro256::new(10_000 + seed);
            // partition_offsets: duplicate-heavy sorted keys, adversarial
            // cuts (equal to keys, extremes, past-the-end)
            let n = rng.next_below(2000) as usize;
            let mut keys: Vec<u64> = (0..n)
                .map(|_| {
                    if rng.next_below(2) == 0 {
                        rng.next_below(64)
                    } else {
                        rng.next_u64()
                    }
                })
                .collect();
            keys.sort_unstable();
            let c = rng.next_below(40) as usize;
            let mut cuts: Vec<u64> = (0..c)
                .map(|_| match rng.next_below(8) {
                    0 => 0,
                    1 => u64::MAX,
                    2 => rng.next_below(64),
                    3 if n > 0 => keys[rng.next_below(n as u64) as usize],
                    _ => rng.next_u64(),
                })
                .collect();
            cuts.sort_unstable();
            assert_eq!(
                radix::partition_offsets(&keys, &cuts),
                reference::partition_offsets(&keys, &cuts),
                "tier {} seed {seed}",
                tier.name()
            );

            // strided key gathers over generated records
            let records = rng.next_below(120);
            let buf = gensort::generate_partition(&gensort::GenSpec {
                seed: 77 + seed,
                offset: 0,
                records,
            });
            assert_eq!(
                sortlib::extract_partition_keys(&buf),
                reference::extract_partition_keys(&buf),
                "tier {} seed {seed} (BE gather)",
                tier.name()
            );
            let keyed_buf = keyed::from_records(&buf);
            assert_eq!(
                keyed::keys_of(&keyed_buf),
                reference::keys_of_keyed(&keyed_buf),
                "tier {} seed {seed} (LE gather)",
                tier.name()
            );
        }
    });
}

#[test]
fn p12_fused_merge_matches_reference_on_all_tiers() {
    for_each_tier(|tier| {
        for seed in 0..CASES / 2 {
            let mut rng = Xoshiro256::new(11_000 + seed);
            let n_runs = rng.next_below(6) as usize; // includes the 0-run case
            let built: Vec<(Vec<u8>, Vec<u8>)> = (0..n_runs)
                .map(|_| {
                    let l = rng.next_below(200) as usize; // includes empty runs
                    let mut recs: Vec<Vec<u8>> = (0..l)
                        .map(|_| {
                            let mut r = vec![0u8; RECORD_SIZE];
                            // low-cardinality keys force cross-run
                            // duplicates, stressing the merge tie-break
                            let k = if rng.next_below(2) == 0 {
                                rng.next_below(32)
                            } else {
                                rng.next_u64()
                            };
                            r[..8].copy_from_slice(&k.to_be_bytes());
                            for b in r[8..].iter_mut() {
                                *b = rng.next_u64() as u8;
                            }
                            r
                        })
                        .collect();
                    recs.sort_by_key(|r| sortlib::partition_key(r));
                    let plain: Vec<u8> = recs.concat();
                    let keyed_run = keyed::from_records(&plain);
                    (plain, keyed_run)
                })
                .collect();
            let plain: Vec<&[u8]> =
                built.iter().map(|(p, _)| p.as_slice()).collect();
            let keyed_runs: Vec<&[u8]> =
                built.iter().map(|(_, k)| k.as_slice()).collect();
            let c = rng.next_below(6) as usize;
            let mut cuts: Vec<u64> = (0..c)
                .map(|_| match rng.next_below(8) {
                    0 => 0,
                    1 => u64::MAX,
                    2 => rng.next_below(32),
                    _ => rng.next_u64(),
                })
                .collect();
            cuts.sort_unstable();
            let total: usize =
                keyed_runs.iter().map(|r| keyed::keyed_record_count(r)).sum();
            let want = reference::merge_then_gather(&plain, &cuts);
            let mut fused = vec![0u8; total * keyed::KEYED_RECORD_SIZE];
            let bb = keyed::merge_keyed_ranges(&keyed_runs, &cuts, &mut fused);
            assert_eq!(bb.len(), cuts.len() + 2, "tier {} seed {seed}", tier.name());
            let got: Vec<Vec<u8>> = bb
                .windows(2)
                .map(|w| keyed::to_records(&fused[w[0]..w[1]]))
                .collect();
            assert_eq!(want, got, "tier {} seed {seed}", tier.name());

            // the record-emitting reduce-path variant too
            let mut flat = vec![0u8; total * RECORD_SIZE];
            let written = keyed::merge_keyed_records(&keyed_runs, &mut flat);
            assert_eq!(written, flat.len(), "tier {} seed {seed}", tier.name());
            assert_eq!(flat, want.concat(), "tier {} seed {seed}", tier.name());
        }
    });
}

#[test]
fn p13_batched_gensort_matches_reference_on_all_tiers() {
    use exoshuffle::util::rng::stream_at;
    for_each_tier(|tier| {
        for seed in 0..CASES / 2 {
            let mut rng = Xoshiro256::new(12_000 + seed);
            let spec = gensort::GenSpec {
                seed: rng.next_u64(),
                offset: rng.next_below(1 << 40),
                records: rng.next_below(300),
            };
            for skew in [
                sortlib::Skew::Uniform,
                sortlib::Skew::Zipf(0.5),
                sortlib::Skew::Zipf(4.0),
            ] {
                assert_eq!(
                    gensort::generate_partition_with(&spec, skew),
                    reference::generate_partition_with(&spec, skew),
                    "tier {} seed {seed} {skew:?}",
                    tier.name()
                );
            }
            // the raw draw stream itself, including wrapping start indices
            let start = if rng.next_below(4) == 0 {
                u64::MAX - rng.next_below(8)
            } else {
                rng.next_u64()
            };
            let len = rng.next_below(70) as usize;
            let mut got = vec![0u64; len];
            simd::stream_block(spec.seed, start, &mut got);
            let want: Vec<u64> = (0..len)
                .map(|j| stream_at(spec.seed, start.wrapping_add(j as u64)))
                .collect();
            assert_eq!(got, want, "tier {} seed {seed}", tier.name());
        }
    });
}

#[test]
fn p6_pipeline_preserves_multiset_across_geometries() {
    for seed in 0..8 {
        let mut rng = Xoshiro256::new(5000 + seed);
        let workers = 1 + rng.next_below(4) as usize;
        let mib = 1 + rng.next_below(4);
        let mut spec = JobSpec::scaled(mib << 20, workers);
        spec.seed = seed * 13 + 1;
        spec.merge_threshold_blocks = 1 + rng.next_below(8) as usize;
        spec.backpressure = rng.next_below(2) == 0;
        let report = run_cloudsort(&spec, Backend::Native).unwrap();
        assert!(
            report.validation.valid,
            "seed {seed}: {:?} spec {:?}",
            report.validation, spec
        );
        assert_eq!(
            report.validation.summary.records,
            spec.total_records(),
            "seed {seed}"
        );
    }
}
