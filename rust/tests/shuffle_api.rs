//! Integration tests of the shuffle-library API: builder defaults,
//! strategy registry and selection, cross-strategy output equivalence,
//! and JobReport stage-name/timing invariants.

use exoshuffle::coordinator::run_cloudsort;
use exoshuffle::prelude::*;
use exoshuffle::shuffle::{list_strategies, strategy_by_name};

/// Builder with no overrides = the paper's two-stage strategy on the
/// native backend against a fresh S3 — identical to `run_cloudsort`.
#[test]
fn builder_defaults_match_run_cloudsort() {
    let spec = JobSpec::scaled(2 << 20, 2);
    let a = ShuffleJob::new(spec.clone()).run().unwrap();
    let b = run_cloudsort(&spec, Backend::Native).unwrap();
    assert!(a.validation.valid && b.validation.valid);
    assert_eq!(a.strategy, "two-stage-merge");
    assert_eq!(a.strategy, b.strategy);
    // deterministic dataset → identical sorted output both ways
    assert_eq!(
        a.validation.summary.checksum,
        b.validation.summary.checksum
    );
    assert_eq!(a.validation.summary.records, b.validation.summary.records);
}

#[test]
fn simple_shuffle_sorts_without_merge_stage() {
    let spec = JobSpec::scaled(2 << 20, 2);
    let report = ShuffleJob::new(spec.clone())
        .strategy(SimpleShuffle)
        .backend(Backend::Native)
        .run()
        .unwrap();
    assert!(report.validation.valid, "{:?}", report.validation);
    assert_eq!(report.strategy, "simple");
    assert_eq!(report.n_merge_tasks, 0);
    assert_eq!(report.n_map_tasks, spec.n_input_partitions);
    assert_eq!(report.n_reduce_tasks, spec.n_output_partitions);
    let stage_names: Vec<&str> =
        report.stages.iter().map(|s| s.name.as_str()).collect();
    assert_eq!(stage_names, ["map", "reduce"]);
    // no merge events in the task log either
    assert_eq!(report.mean_task_secs("merge"), 0.0);
}

/// The library claim: different stage topologies, byte-identical
/// validated output on the same job spec.
#[test]
fn strategies_produce_identical_validated_output() {
    let spec = JobSpec::scaled(4 << 20, 3);
    let two_stage = ShuffleJob::new(spec.clone())
        .strategy(TwoStageMerge)
        .run()
        .unwrap();
    let simple = ShuffleJob::new(spec.clone())
        .strategy(SimpleShuffle)
        .run()
        .unwrap();
    let streaming = ShuffleJob::new(spec.clone())
        .strategy(StreamingShuffle)
        .run()
        .unwrap();
    assert!(two_stage.validation.valid);
    assert!(simple.validation.valid);
    assert!(streaming.validation.valid);
    for other in [&simple, &streaming] {
        assert_eq!(
            two_stage.validation.summary.records,
            other.validation.summary.records
        );
        assert_eq!(
            two_stage.validation.summary.checksum,
            other.validation.summary.checksum
        );
        assert_eq!(
            two_stage.validation.summary.duplicates,
            other.validation.summary.duplicates
        );
    }
}

/// The streaming strategy submits the whole DAG up front: same task
/// structure as two-stage (threshold-sized merge batches per worker,
/// one reduce per output partition), one fused stage, valid output.
#[test]
fn streaming_shuffle_submits_the_full_dag_without_barriers() {
    let spec = JobSpec::scaled(4 << 20, 2);
    let report = ShuffleJob::new(spec.clone())
        .strategy(StreamingShuffle)
        .backend(Backend::Native)
        .run()
        .unwrap();
    assert!(report.validation.valid, "{:?}", report.validation);
    assert_eq!(report.strategy, "streaming");
    let stage_names: Vec<&str> =
        report.stages.iter().map(|s| s.name.as_str()).collect();
    assert_eq!(stage_names, ["streaming"], "no driver-visible stage split");
    assert_eq!(report.n_map_tasks, spec.n_input_partitions);
    assert_eq!(
        report.n_merge_tasks,
        spec.merge_batches_per_node() * spec.n_workers()
    );
    assert_eq!(report.n_reduce_tasks, spec.n_output_partitions);
    // merges really ran (events), and the exposure gauge saw blocks land
    assert!(report.mean_task_secs("merge") > 0.0);
    assert!(report.peak_unmerged_blocks >= 1);
}

#[test]
fn strategy_selection_by_registry_name() {
    let spec = JobSpec::scaled(1 << 20, 2);
    let strategy = strategy_by_name("simple").expect("registered");
    let report = ShuffleJob::new(spec)
        .strategy_arc(strategy)
        .backend(Backend::Native)
        .run()
        .unwrap();
    assert!(report.validation.valid);
    assert_eq!(report.strategy, "simple");
    assert!(strategy_by_name("no-such-strategy").is_none());
}

#[test]
fn registry_lists_all_builtin_strategies() {
    let names: Vec<&str> =
        list_strategies().iter().map(|s| s.name()).collect();
    assert!(names.contains(&"two-stage-merge"));
    assert!(names.contains(&"simple"));
    assert!(names.contains(&"streaming"));
}

/// Stage timings must use the strategy-declared names, in order, sum to
/// the total, and feed the Table 1 compatibility accessors.
#[test]
fn report_stage_invariants() {
    for (run_simple, expected) in
        [(false, vec!["map_shuffle", "reduce"]), (true, vec!["map", "reduce"])]
    {
        let spec = JobSpec::scaled(1 << 20, 2);
        let job = ShuffleJob::new(spec).backend(Backend::Native);
        let report = if run_simple {
            job.strategy(SimpleShuffle).run().unwrap()
        } else {
            job.strategy(TwoStageMerge).run().unwrap()
        };
        let names: Vec<&str> =
            report.stages.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, expected);
        assert!(report.stages.iter().all(|s| s.secs >= 0.0));
        let sum: f64 = report.stages.iter().map(|s| s.secs).sum();
        assert!(
            (sum - report.total_secs).abs() < 1e-9,
            "stages {sum} != total {}",
            report.total_secs
        );
        assert!(
            (report.map_shuffle_secs() + report.reduce_secs()
                - report.total_secs)
                .abs()
                < 1e-9
        );
        let (ms, rd, tot) = report.table1_row();
        assert!((ms + rd - tot).abs() < 1e-9);
        // unknown families/stages are 0.0, never NaN (regression test)
        assert_eq!(report.stage_secs("no-such-stage"), 0.0);
        let unknown = report.mean_task_secs("no-such-family");
        assert_eq!(unknown, 0.0);
        assert!(!unknown.is_nan());
    }
}

/// `.on(&s3)` runs against the caller's store: fault injection reaches
/// the strategy's tasks through the builder path.
#[test]
fn builder_on_custom_s3_sees_faults() {
    use exoshuffle::s3sim::faults::FaultPlan;
    let spec = JobSpec::scaled(1 << 20, 2);
    let s3 = S3::with_buckets(spec.s3_buckets);
    s3.set_faults(FaultPlan::with_probability(0.1, 0xBEEF));
    let report = ShuffleJob::new(spec)
        .strategy(SimpleShuffle)
        .on(&s3)
        .run()
        .unwrap();
    assert!(report.validation.valid);
    assert!(report.s3.failed_requests > 0, "faults should have fired");
}
