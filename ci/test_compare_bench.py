#!/usr/bin/env python3
"""Self-tests for ci/compare_bench.py (the perf gate's brain).

Run directly — no pytest dependency, CI invokes it as a plain script:

    python3 ci/test_compare_bench.py

Covers the four paths the perf gate can take:
  - pass: ratio + regression gates all green end-to-end (exit 0);
  - fail: speedup below floor / missing twin / regression over the 20%
    tolerance / missing files (exit 1, with the right failure strings);
  - update: --update-baselines rewrites ci/baselines with
    ``provisional: false`` and round-trips through load_results;
  - armed: --require-armed turns a provisional baseline or missing
    [scalar]/[simd] pairs from a warning into a hard failure.
"""

import contextlib
import io
import json
import os
import sys
import tempfile
import unittest
from unittest import mock

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import compare_bench as cb


def entry(name, mean_secs, allocs=0, bytes_=0, smoke=True):
    e = {"name": name, "mean_secs": mean_secs, "smoke": smoke}
    if allocs:
        e["allocs"] = allocs
    if bytes_:
        e["bytes"] = bytes_
    return e


def kernels_results(sort_speedup=3.0, simd_ratio=2.0):
    """A healthy kernels run: ref/opt and scalar/simd pairs for the
    gated families, with the requested within-run ratios."""
    return [
        entry("sort 1M [ref]", 0.3),
        entry("sort 1M [opt]", 0.3 / sort_speedup, bytes_=100_000_000),
        entry("merge 8-way [ref]", 0.4, allocs=1000),
        entry("merge 8-way [opt]", 0.4 / sort_speedup, allocs=10),
        entry("maplike pipeline [ref]", 0.2, allocs=5000),
        entry("maplike pipeline [opt]", 0.15, allocs=100),
        entry("sort 1M [scalar]", 0.2),
        entry("sort 1M [simd]", 0.2 / simd_ratio),
        entry("merge 8-way [scalar]", 0.2),
        entry("merge 8-way [simd]", 0.2 / simd_ratio),
    ]


def write_bench(dirpath, bench, results, provisional=None):
    os.makedirs(dirpath, exist_ok=True)
    path = os.path.join(dirpath, f"BENCH_{bench}.json")
    data = results if provisional is None else {
        "provisional": provisional,
        "results": results,
    }
    with open(path, "w") as f:
        json.dump(data, f)
    return path


@contextlib.contextmanager
def quiet():
    """Swallow the gate's table output; yield it for assertions."""
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        yield buf


class LoadResultsTest(unittest.TestCase):
    def test_bare_array_is_a_non_provisional_run(self):
        with tempfile.TemporaryDirectory() as d:
            p = write_bench(d, "kernels", [entry("sort [ref]", 0.1)])
            loaded = cb.load_results(p)
        self.assertFalse(loaded["provisional"])
        self.assertEqual(len(loaded["results"]), 1)

    def test_baseline_object_keeps_its_provisional_flag(self):
        with tempfile.TemporaryDirectory() as d:
            p = write_bench(d, "kernels", [], provisional=True)
            loaded = cb.load_results(p)
        self.assertTrue(loaded["provisional"])
        self.assertEqual(loaded["results"], [])


class HelpersTest(unittest.TestCase):
    def test_family_is_the_first_word(self):
        self.assertEqual(cb.family("sort 1M [ref]"), "sort")
        self.assertEqual(cb.family("merge=8 way"), "merge")

    def test_pair_up_twins_ref_with_opt(self):
        results = kernels_results()
        pairs = {base: (ref, opt) for base, _, ref, opt in cb.pair_up(results)}
        self.assertIn("sort 1M", pairs)
        self.assertIsNotNone(pairs["sort 1M"][1])
        # scalar/simd pairing uses the alternate suffixes
        simd = list(cb.pair_up(results, " [scalar]", " [simd]"))
        self.assertEqual(len(simd), 2)

    def test_pair_up_reports_missing_twin_as_none(self):
        results = [entry("sort 1M [ref]", 0.1)]
        [(_, _, _, opt)] = list(cb.pair_up(results))
        self.assertIsNone(opt)

    def test_gbps_needs_bytes_and_positive_time(self):
        self.assertIsNone(cb.gbps(entry("x", 0.1)))
        self.assertAlmostEqual(
            cb.gbps(entry("x", 0.5, bytes_=1_000_000_000)), 2.0
        )


class RatioGateTest(unittest.TestCase):
    def run_gate(self, results):
        failures, rows = [], []
        with quiet():
            cb.check_ratios(results, failures, rows)
        return failures, rows

    def test_healthy_run_passes(self):
        failures, rows = self.run_gate(kernels_results())
        self.assertEqual(failures, [])
        self.assertTrue(all(r["ok"] for r in rows))

    def test_speedup_below_floor_fails(self):
        failures, _ = self.run_gate(kernels_results(sort_speedup=1.2))
        self.assertTrue(any("speedup 1.20x" in f for f in failures))

    def test_missing_opt_twin_fails(self):
        failures, _ = self.run_gate([entry("sort 1M [ref]", 0.1)])
        self.assertTrue(any("no [opt] twin" in f for f in failures))

    def test_no_pairs_at_all_fails(self):
        failures, _ = self.run_gate([entry("loose entry", 0.1)])
        self.assertTrue(any("no [ref]/[opt]" in f for f in failures))

    def test_alloc_ratio_below_floor_fails_on_counting_builds(self):
        results = kernels_results()
        for e in results:
            if e["name"] == "merge 8-way [opt]":
                e["allocs"] = 900  # 1000/900 ≈ 1.1x < 5x floor
        failures, _ = self.run_gate(results)
        self.assertTrue(any("alloc ratio" in f for f in failures))

    def test_alloc_gate_skipped_without_alloc_stats(self):
        results = [
            entry("merge 8-way [ref]", 0.4),
            entry("merge 8-way [opt]", 0.1),
        ]
        failures, _ = self.run_gate(results)
        self.assertEqual(failures, [])


class SimdGateTest(unittest.TestCase):
    def run_gate(self, results, require_armed=False):
        failures, rows = [], []
        with quiet():
            cb.check_simd_ratios(results, failures, require_armed, rows)
        return failures, rows

    def test_healthy_run_passes(self):
        failures, rows = self.run_gate(kernels_results())
        self.assertEqual(failures, [])
        self.assertEqual(len(rows), 2)

    def test_ratio_below_floor_fails(self):
        failures, _ = self.run_gate(kernels_results(simd_ratio=1.1))
        self.assertTrue(any("simd/scalar 1.10x" in f for f in failures))

    def test_missing_pairs_is_a_warning_when_unarmed(self):
        failures, _ = self.run_gate([entry("sort 1M [ref]", 0.1)])
        self.assertEqual(failures, [])

    def test_missing_pairs_fails_when_armed(self):
        failures, _ = self.run_gate(
            [entry("sort 1M [ref]", 0.1)], require_armed=True
        )
        self.assertTrue(any("--require-armed" in f for f in failures))


class RegressionGateTest(unittest.TestCase):
    def run_gate(self, current, baseline, require_armed=False):
        failures = []
        with quiet():
            cb.check_regressions(
                "kernels", current, baseline, failures, require_armed
            )
        return failures

    def wrap(self, results, provisional=False):
        return {"provisional": provisional, "results": results}

    def test_within_tolerance_passes(self):
        base = self.wrap([entry("sort 1M [opt]", 0.100)])
        cur = self.wrap([entry("sort 1M [opt]", 0.115)])  # +15% < 20%
        self.assertEqual(self.run_gate(cur, base), [])

    def test_regression_over_tolerance_fails(self):
        base = self.wrap([entry("sort 1M [opt]", 0.100)])
        cur = self.wrap([entry("sort 1M [opt]", 0.130)])  # +30% > 20%
        failures = self.run_gate(cur, base)
        self.assertTrue(any("baseline 0.100000s" in f for f in failures))

    def test_different_smoke_scales_are_not_compared(self):
        base = self.wrap([entry("sort 1M [opt]", 0.100, smoke=False)])
        cur = self.wrap([entry("sort 1M [opt]", 9.999, smoke=True)])
        self.assertEqual(self.run_gate(cur, base), [])

    def test_provisional_baseline_warns_when_unarmed(self):
        base = self.wrap([], provisional=True)
        cur = self.wrap([entry("sort 1M [opt]", 9.999)])
        self.assertEqual(self.run_gate(cur, base), [])

    def test_provisional_baseline_fails_when_armed(self):
        base = self.wrap([], provisional=True)
        cur = self.wrap([entry("sort 1M [opt]", 0.1)])
        failures = self.run_gate(cur, base, require_armed=True)
        self.assertTrue(any("still provisional" in f for f in failures))


class UpdateBaselinesTest(unittest.TestCase):
    def test_update_writes_armed_baselines(self):
        with tempfile.TemporaryDirectory() as d:
            current = os.path.join(d, "current")
            baselines = os.path.join(d, "baselines")
            for bench in cb.BENCHES:
                write_bench(current, bench, [entry(f"{bench} x", 0.1)])
            with quiet():
                cb.update_baselines(current, baselines)
            for bench in cb.BENCHES:
                loaded = cb.load_results(
                    os.path.join(baselines, f"BENCH_{bench}.json")
                )
                self.assertFalse(loaded["provisional"])
                self.assertEqual(len(loaded["results"]), 1)

    def test_update_skips_missing_benches(self):
        with tempfile.TemporaryDirectory() as d:
            current = os.path.join(d, "current")
            baselines = os.path.join(d, "baselines")
            os.makedirs(current)
            with quiet():
                cb.update_baselines(current, baselines)
            self.assertEqual(
                [f for f in os.listdir(baselines) if f.endswith(".json")], []
            )


class MainEndToEndTest(unittest.TestCase):
    """Full CLI paths through main(): pass, fail, update, armed."""

    def populate(self, d, provisional=False):
        current = os.path.join(d, "current")
        baselines = os.path.join(d, "baselines")
        write_bench(current, "kernels", kernels_results())
        write_bench(current, "sched_overhead", [entry("submit_wave", 0.01)])
        write_bench(current, "fig1", [entry("fig1 e2e", 0.5)])
        for bench in cb.BENCHES:
            src = cb.load_results(
                os.path.join(current, f"BENCH_{bench}.json")
            )["results"]
            write_bench(baselines, bench, src, provisional=provisional)
        return current, baselines

    def run_main(self, argv):
        with mock.patch.object(sys, "argv", ["compare_bench.py"] + argv):
            with quiet() as buf:
                code = cb.main()
        return code, buf.getvalue()

    def test_pass_path(self):
        with tempfile.TemporaryDirectory() as d:
            current, baselines = self.populate(d)
            code, out = self.run_main(
                ["--current", current, "--baselines", baselines]
            )
        self.assertEqual(code, 0)
        self.assertIn("perf gate PASSED", out)

    def test_armed_pass_path(self):
        with tempfile.TemporaryDirectory() as d:
            current, baselines = self.populate(d, provisional=False)
            code, _ = self.run_main(
                ["--current", current, "--baselines", baselines,
                 "--require-armed"]
            )
        self.assertEqual(code, 0)

    def test_provisional_warns_unarmed_but_fails_armed(self):
        with tempfile.TemporaryDirectory() as d:
            current, baselines = self.populate(d, provisional=True)
            code, out = self.run_main(
                ["--current", current, "--baselines", baselines]
            )
            self.assertEqual(code, 0)
            self.assertIn("::warning", out)
            code, out = self.run_main(
                ["--current", current, "--baselines", baselines,
                 "--require-armed"]
            )
        self.assertEqual(code, 1)
        self.assertIn("still provisional", out)

    def test_regression_fails(self):
        with tempfile.TemporaryDirectory() as d:
            current, baselines = self.populate(d)
            slow = [entry("fig1 e2e", 0.9)]  # baseline 0.5 → +80%
            write_bench(current, "fig1", slow)
            code, out = self.run_main(
                ["--current", current, "--baselines", baselines]
            )
        self.assertEqual(code, 1)
        self.assertIn("perf gate FAILED", out)

    def test_missing_current_file_fails(self):
        with tempfile.TemporaryDirectory() as d:
            current, baselines = self.populate(d)
            os.remove(os.path.join(current, "BENCH_fig1.json"))
            code, out = self.run_main(
                ["--current", current, "--baselines", baselines]
            )
        self.assertEqual(code, 1)
        self.assertIn("missing", out)

    def test_update_path_rewrites_and_exits_zero(self):
        with tempfile.TemporaryDirectory() as d:
            current, _ = self.populate(d)
            fresh = os.path.join(d, "fresh-baselines")
            code, _ = self.run_main(
                ["--current", current, "--baselines", fresh,
                 "--update-baselines"]
            )
            self.assertEqual(code, 0)
            loaded = cb.load_results(
                os.path.join(fresh, "BENCH_kernels.json")
            )
            self.assertFalse(loaded["provisional"])
            # the freshly written baselines must pass their own gate
            code, _ = self.run_main(
                ["--current", current, "--baselines", fresh,
                 "--require-armed"]
            )
        self.assertEqual(code, 0)

    def test_step_summary_is_written_when_env_set(self):
        with tempfile.TemporaryDirectory() as d:
            current, baselines = self.populate(d)
            summary = os.path.join(d, "summary.md")
            with mock.patch.dict(
                os.environ, {"GITHUB_STEP_SUMMARY": summary}
            ):
                code, _ = self.run_main(
                    ["--current", current, "--baselines", baselines]
                )
            self.assertEqual(code, 0)
            with open(summary) as f:
                text = f.read()
        self.assertIn("Perf gate", text)
        self.assertIn("PASSED", text)


if __name__ == "__main__":
    unittest.main(verbosity=1)
