#!/usr/bin/env python3
"""CI perf gate over the BENCH_*.json files the bench binaries emit.

Three kinds of checks:

1. Within-run ratio gates (hardware-independent, always enforced) on
   BENCH_kernels.json: every ``<family> ... [ref]`` / ``[opt]`` entry
   pair from benches/kernels.rs is compared in the *same* run on the
   *same* machine, so the thresholds hold regardless of runner speed.
     - speedup (ref.mean_secs / opt.mean_secs) >= 1.5 for the ``sort``
       and ``merge`` families;
     - heap-allocation ratio (ref.allocs / opt.allocs) >= 5.0 for the
       ``merge`` and ``maplike`` (map-task data path) families — only
       checked when the benches were built with ``--features
       alloc-stats`` (otherwise allocs are all zero and the gate is
       skipped with a notice).

2. SIMD dispatch ratio gate (also within-run) on BENCH_kernels.json:
   every ``[scalar]`` / ``[simd]`` pair pins the *same* kernel to the
   scalar tier vs the best vector tier (sortlib::simd), so the ratio
   isolates the vectorization win from the algorithm rewrite measured
   by check 1. Required: simd >= 1.3x scalar on the ``sort`` and
   ``merge`` families. A bench host whose best tier is scalar emits no
   pairs; that is a loud warning normally and a failure under
   ``--require-armed`` (CI's x86_64 runners always have at least SSE2,
   so absent pairs there mean the dispatch is broken, not the host).

3. Regression gate vs committed baselines (ci/baselines/BENCH_*.json):
   any entry whose name appears in a non-provisional baseline must not
   regress mean_secs by more than 20%. Baselines carry a ``provisional``
   flag: the repo ships provisional (empty) baselines because the
   authoring environment has no Rust toolchain to produce real numbers;
   provisional baselines skip this gate loudly instead of vacuously
   passing against made-up numbers. Every provisional skip emits a
   GitHub Actions ``::warning::`` annotation so the disarmed gate shows
   up on the run summary, not just in a scrolled-past log; pass
   ``--require-armed`` to turn any provisional skip into a hard failure
   (use this once baselines have been refreshed, so a regression to
   ``provisional: true`` cannot silently disarm the gate again).

Per-kernel throughput: entries that carry a ``bytes`` field (payload
bytes per iteration) get a derived GB/s column, both in the log table
and in the $GITHUB_STEP_SUMMARY markdown this script appends when that
variable is set.

Refreshing baselines: the ``refresh-baselines`` workflow
(.github/workflows/refresh-baselines.yml) runs the bench suite on the
pinned CI runner class and commits the rewritten, ``provisional:
false`` baselines. To refresh by hand on that same machine class:

    BENCH_SMOKE=1 BENCH_JSON_DIR=bench-current \
        cargo bench --features alloc-stats --bench kernels \
        && cargo bench --bench sched_overhead && cargo bench --bench fig1
    python3 ci/compare_bench.py --current bench-current --update-baselines

then commit the rewritten ci/baselines/*.json (now provisional: false).

Exit status: 0 when every enforced gate passes, 1 otherwise.
"""

import argparse
import json
import os
import sys

BENCHES = ["kernels", "sched_overhead", "fig1"]

# ref/opt speedup floors per kernels-bench family (first word of the
# entry name). maplike is reported but not speed-gated: it is the
# allocation-hygiene pair.
SPEEDUP_MIN = {"sort": 1.5, "merge": 1.5}

# scalar/simd dispatch-ratio floors: the vector tier must beat the
# scalar tier of the *same* kernel by this much.
SIMD_RATIO_MIN = {"sort": 1.3, "merge": 1.3}

# ref/opt heap-allocation floors (alloc-stats builds only).
ALLOC_RATIO_MIN = {"merge": 5.0, "maplike": 5.0}

# Regression tolerance vs non-provisional baselines.
REGRESSION_TOLERANCE = 0.20


def load_results(path):
    """Load a bench JSON file: a bare result array (bench output) or a
    {"provisional": bool, "results": [...]} baseline object."""
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, list):
        return {"provisional": False, "results": data}
    return {
        "provisional": bool(data.get("provisional", False)),
        "results": data.get("results", []),
    }


def family(name):
    return name.split(" ", 1)[0].split("=", 1)[0]


def pair_up(results, ref_suffix=" [ref]", opt_suffix=" [opt]"):
    """Yield (base_name, family, ref_entry, opt_entry_or_None) for every
    ``ref_suffix`` entry in a kernels result list, twinned with its
    ``opt_suffix`` entry of the same base name."""
    by_name = {r["name"]: r for r in results}
    for name, ref in sorted(by_name.items()):
        if not name.endswith(ref_suffix):
            continue
        base = name[: -len(ref_suffix)]
        yield base, family(base), ref, by_name.get(base + opt_suffix)


def gbps(entry):
    """Derived throughput in GB/s, or None when the entry carries no
    payload-size (``bytes``) field."""
    b = entry.get("bytes", 0)
    m = entry.get("mean_secs", 0.0)
    if not b or m <= 0:
        return None
    return b / m / 1e9


def fmt_gbps(entry):
    g = gbps(entry)
    return f"{g:.2f}" if g is not None else "-"


def check_ratios(results, failures, rows):
    """Within-run speedup + allocation gates on kernels results."""
    counting = any(r.get("allocs", 0) > 0 for r in results)
    pairs = list(pair_up(results))
    if not pairs:
        failures.append("kernels: no [ref]/[opt] entry pairs found")
        return
    for base, fam, ref, opt in pairs:
        if opt is None:
            failures.append(f"kernels: '{base} [ref]' has no [opt] twin")
            continue
        speedup = ref["mean_secs"] / max(opt["mean_secs"], 1e-12)
        floor = SPEEDUP_MIN.get(fam)
        gated = floor is not None
        ok = not (gated and speedup < floor)
        if not ok:
            failures.append(
                f"kernels: {base}: speedup {speedup:.2f}x < required {floor}x"
            )
        print(
            f"  {'    ' if ok else 'FAIL'} {base}: {speedup:.2f}x speedup, "
            f"{fmt_gbps(opt)} GB/s opt"
            + (f" (floor {floor}x)" if gated else " (informational)")
        )
        rows.append(
            {
                "pair": base,
                "kind": "opt/ref",
                "ratio": speedup,
                "floor": floor,
                "gbps": fmt_gbps(opt),
                "ok": ok,
            }
        )
        afloor = ALLOC_RATIO_MIN.get(fam)
        if afloor is None:
            continue
        if not counting:
            print(f"       {base}: alloc gate skipped (no alloc-stats build)")
            continue
        ref_allocs = ref.get("allocs", 0)
        opt_allocs = opt.get("allocs", 0)
        if ref_allocs == 0:
            failures.append(f"kernels: {base}: ref allocs are 0 despite alloc-stats")
            continue
        ratio = ref_allocs / max(opt_allocs, 1)
        if opt_allocs > 0 and ratio < afloor:
            failures.append(
                f"kernels: {base}: alloc ratio {ratio:.1f}x "
                f"({ref_allocs} ref / {opt_allocs} opt) < required {afloor}x"
            )
            print(f"  FAIL {base}: alloc ratio {ratio:.1f}x (floor {afloor}x)")
        else:
            print(
                f"       {base}: alloc ratio {ratio:.1f}x "
                f"({ref_allocs} ref / {opt_allocs} opt, floor {afloor}x)"
            )


def check_simd_ratios(results, failures, require_armed, rows):
    """Within-run [scalar]/[simd] dispatch-ratio gate on kernels results."""
    pairs = list(pair_up(results, " [scalar]", " [simd]"))
    if not pairs:
        msg = (
            "kernels: no [scalar]/[simd] pairs in the bench output — the "
            "bench host's best dispatch tier is scalar (or the simd "
            "family was dropped). On CI's x86_64 runners at least SSE2 "
            "is always available, so this means broken dispatch there."
        )
        print(f"::warning title=simd dispatch gate unarmed::{msg}")
        print(f"  {msg}")
        if require_armed:
            failures.append(
                "kernels: --require-armed is set but no [scalar]/[simd] "
                "pairs were emitted"
            )
        return
    for base, fam, scalar, simd in pairs:
        if simd is None:
            failures.append(f"kernels: '{base} [scalar]' has no [simd] twin")
            continue
        ratio = scalar["mean_secs"] / max(simd["mean_secs"], 1e-12)
        floor = SIMD_RATIO_MIN.get(fam)
        gated = floor is not None
        ok = not (gated and ratio < floor)
        if not ok:
            failures.append(
                f"kernels: {base}: simd/scalar {ratio:.2f}x < required {floor}x"
            )
        print(
            f"  {'    ' if ok else 'FAIL'} {base}: {ratio:.2f}x simd/scalar, "
            f"{fmt_gbps(simd)} GB/s simd"
            + (f" (floor {floor}x)" if gated else " (informational)")
        )
        rows.append(
            {
                "pair": base,
                "kind": "simd/scalar",
                "ratio": ratio,
                "floor": floor,
                "gbps": fmt_gbps(simd),
                "ok": ok,
            }
        )


def check_regressions(bench, current, baseline, failures, require_armed):
    """mean_secs regression gate vs a committed baseline."""
    if baseline["provisional"]:
        msg = (
            f"{bench}: baseline is provisional — 20% regression gate "
            "skipped. Refresh ci/baselines/BENCH_*.json via the "
            "refresh-baselines workflow (or --update-baselines on a "
            "CI-class machine; see the module docstring or the README's "
            "'Perf gate' section)."
        )
        # GitHub Actions annotation: surfaces on the run summary page so
        # a never-armed gate cannot hide in the log forever
        print(f"::warning title=perf regression gate disarmed::{msg}")
        print(f"  {msg}")
        if require_armed:
            failures.append(
                f"{bench}: --require-armed is set but the baseline is "
                "still provisional"
            )
        return
    base_by_name = {r["name"]: r for r in baseline["results"]}
    compared = 0
    for cur in current["results"]:
        base = base_by_name.get(cur["name"])
        if base is None:
            continue
        if cur.get("smoke") != base.get("smoke"):
            continue  # different scales are not comparable
        compared += 1
        limit = base["mean_secs"] * (1.0 + REGRESSION_TOLERANCE)
        if cur["mean_secs"] > limit:
            failures.append(
                f"{bench}: {cur['name']}: {cur['mean_secs']:.6f}s > "
                f"{limit:.6f}s (baseline {base['mean_secs']:.6f}s "
                f"+{REGRESSION_TOLERANCE:.0%})"
            )
    print(f"  {bench}: {compared} entries compared against baseline")


def write_step_summary(rows, failures):
    """Append a per-kernel markdown table to $GITHUB_STEP_SUMMARY (a
    no-op outside GitHub Actions)."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path or not rows:
        return
    lines = [
        "### Perf gate: per-kernel ratios and throughput",
        "",
        "| kernel | ratio | floor | GB/s | status |",
        "|---|---|---|---|---|",
    ]
    for r in rows:
        floor = f"{r['floor']}x" if r["floor"] is not None else "info"
        status = "✅" if r["ok"] else "❌"
        lines.append(
            f"| {r['pair']} ({r['kind']}) | {r['ratio']:.2f}x | {floor} "
            f"| {r['gbps']} | {status} |"
        )
    lines.append("")
    lines.append(
        f"**{'FAILED' if failures else 'PASSED'}**"
        + (f" — {len(failures)} failure(s)" if failures else "")
    )
    lines.append("")
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def update_baselines(current_dir, baseline_dir):
    os.makedirs(baseline_dir, exist_ok=True)
    for bench in BENCHES:
        src = os.path.join(current_dir, f"BENCH_{bench}.json")
        if not os.path.exists(src):
            print(f"skip {bench}: {src} not found")
            continue
        results = load_results(src)["results"]
        dst = os.path.join(baseline_dir, f"BENCH_{bench}.json")
        with open(dst, "w") as f:
            json.dump({"provisional": False, "results": results}, f, indent=2)
            f.write("\n")
        print(f"wrote {dst} ({len(results)} entries)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--current",
        required=True,
        help="directory with this run's BENCH_*.json (i.e. $BENCH_JSON_DIR)",
    )
    ap.add_argument("--baselines", default="ci/baselines")
    ap.add_argument(
        "--update-baselines",
        action="store_true",
        help="rewrite the committed baselines from --current and exit",
    )
    ap.add_argument(
        "--require-armed",
        action="store_true",
        help="fail (instead of warn) when a baseline is provisional or "
        "the [scalar]/[simd] pairs are missing — set once real baselines "
        "are committed and CI runs on vector-capable hosts",
    )
    args = ap.parse_args()

    if args.update_baselines:
        update_baselines(args.current, args.baselines)
        return 0

    failures = []
    rows = []

    kernels_path = os.path.join(args.current, "BENCH_kernels.json")
    print("ratio gates (within-run, hardware-independent):")
    if os.path.exists(kernels_path):
        kernels = load_results(kernels_path)["results"]
        check_ratios(kernels, failures, rows)
        print("simd dispatch gates (within-run, [scalar] vs [simd] tier):")
        check_simd_ratios(kernels, failures, args.require_armed, rows)
    else:
        failures.append(f"missing {kernels_path}")

    print("regression gates (vs committed baselines):")
    for bench in BENCHES:
        cur_path = os.path.join(args.current, f"BENCH_{bench}.json")
        base_path = os.path.join(args.baselines, f"BENCH_{bench}.json")
        if not os.path.exists(cur_path):
            failures.append(f"missing {cur_path}")
            continue
        if not os.path.exists(base_path):
            failures.append(f"missing baseline {base_path}")
            continue
        check_regressions(
            bench,
            load_results(cur_path),
            load_results(base_path),
            failures,
            args.require_armed,
        )

    write_step_summary(rows, failures)

    if failures:
        print("\nperf gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nperf gate PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
