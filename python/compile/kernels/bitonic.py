"""Bitonic compare-exchange networks over (u64 key, u32 value) pairs.

These are the building blocks of the L1 Pallas kernels. Everything here is
a pure, shape-static jnp function: no gathers, only reshapes and selects,
so the network vectorizes on TPU VPU lanes and lowers to plain HLO under
``pl.pallas_call(..., interpret=True)``.

The comparison order is lexicographic on (key, value). Values are unique
payload indices in our use, which makes the order total and the network
deterministic even with duplicate keys.

Hardware adaptation note (DESIGN.md §Hardware-Adaptation): the paper sorts
100-byte records with a comparison sort on CPU. Here we sort 12-byte
(key, index) pairs with a data-independent compare-exchange network — the
shape-static form AOT lowering requires, and the form that maps onto VPU
lanes rather than scalar branches.
"""

from __future__ import annotations

import jax.numpy as jnp


def _log2(n: int) -> int:
    """Exact log2 of a positive power of two (raises otherwise)."""
    if n <= 0 or (n & (n - 1)) != 0:
        raise ValueError(f"expected a positive power of two, got {n}")
    return n.bit_length() - 1


def compare_exchange(keys, vals, span: int, ascending_rows=None):
    """One compare-exchange stage at distance ``span``.

    Elements ``i`` and ``i ^ span`` are compared; each pair is put in
    ascending or descending order according to ``ascending_rows``, a bool
    array over the ``n // (2 * span)`` pair-rows (``None`` = all ascending).

    Implemented gather-free: reshape to (rows, 2, span) so partners sit on
    axis 1, then a vectorized conditional swap.
    """
    n = keys.shape[0]
    rows = n // (2 * span)
    kr = keys.reshape(rows, 2, span)
    vr = vals.reshape(rows, 2, span)
    k0, k1 = kr[:, 0, :], kr[:, 1, :]
    v0, v1 = vr[:, 0, :], vr[:, 1, :]
    less = (k0 < k1) | ((k0 == k1) & (v0 < v1))
    if ascending_rows is None:
        swap = ~less
    else:
        asc = ascending_rows.reshape(rows, 1)
        swap = jnp.where(asc, ~less, less)
    nk0 = jnp.where(swap, k1, k0)
    nk1 = jnp.where(swap, k0, k1)
    nv0 = jnp.where(swap, v1, v0)
    nv1 = jnp.where(swap, v0, v1)
    keys = jnp.stack([nk0, nk1], axis=1).reshape(n)
    vals = jnp.stack([nv0, nv1], axis=1).reshape(n)
    return keys, vals


def _stage_directions(n: int, k: int, span: int):
    """Ascending flags per pair-row for sort stage ``k`` (block size 2^k).

    Element ``i`` belongs to an ascending block iff bit ``k`` of ``i`` is 0.
    A pair-row at distance ``span`` covers indices [r*2*span, (r+1)*2*span),
    and since 2^k >= 2*span within a stage, the bit is constant per row.
    """
    rows = n // (2 * span)
    row_start = jnp.arange(rows, dtype=jnp.uint32) * jnp.uint32(2 * span)
    return ((row_start >> jnp.uint32(k)) & jnp.uint32(1)) == 0


def bitonic_sort_pairs(keys, vals):
    """Full bitonic sort of (keys, vals) ascending by (key, val).

    O(n log^2 n) compare-exchanges; n must be a power of two.
    """
    n = keys.shape[0]
    logn = _log2(n)
    for k in range(1, logn + 1):
        for j in range(k - 1, -1, -1):
            span = 1 << j
            if k == logn:
                asc = None  # final stage: globally ascending
            else:
                asc = _stage_directions(n, k, span)
            keys, vals = compare_exchange(keys, vals, span, asc)
    return keys, vals


def bitonic_merge_rows(keys, vals):
    """Merge each row of (R, L) from a bitonic sequence to ascending order.

    Callers make each row bitonic by concatenating one ascending run with
    one reversed (descending) run. O(L log L) compare-exchanges.
    """
    r, l = keys.shape
    logl = _log2(l)
    kf = keys.reshape(r * l)
    vf = vals.reshape(r * l)
    for j in range(logl - 1, -1, -1):
        span = 1 << j
        # All pair-rows ascend, but pairs must not straddle row boundaries:
        # span <= l/2 guarantees that, since rows have power-of-two length.
        kf, vf = _merge_stage_within_rows(kf, vf, span, l)
    return kf.reshape(r, l), vf.reshape(r, l)


def _merge_stage_within_rows(kf, vf, span: int, row_len: int):
    """Ascending compare-exchange at ``span``, rows of ``row_len`` flat."""
    # Identical to compare_exchange with all-ascending direction; row
    # boundaries are respected because row_len % (2 * span) == 0.
    assert row_len % (2 * span) == 0
    return compare_exchange(kf, vf, span, None)


def merge_sorted_runs(keys, vals):
    """Merge R ascending runs (rows of (R, L)) into one ascending sequence.

    R and L must be powers of two. log2(R) rounds of pairwise bitonic
    merges: at each round the odd runs are reversed so each concatenated
    pair is bitonic, then merged. O(n log R * log L')-ish compare-exchanges
    -- asymptotically cheaper than re-sorting (O(n log^2 n)).
    Returns flat (keys, vals) of length R * L.
    """
    r, l = keys.shape
    _log2(r), _log2(l)  # validate powers of two
    while r > 1:
        # Reverse odd rows so (even ++ reversed(odd)) is bitonic.
        kr = keys.reshape(r // 2, 2, l)
        vr = vals.reshape(r // 2, 2, l)
        khi = kr[:, 1, ::-1]
        vhi = vr[:, 1, ::-1]
        keys = jnp.concatenate([kr[:, 0, :], khi], axis=1)
        vals = jnp.concatenate([vr[:, 0, :], vhi], axis=1)
        keys, vals = bitonic_merge_rows(keys, vals)
        r //= 2
        l *= 2
    return keys.reshape(l), vals.reshape(l)
