"""L1 Pallas kernel: sort a block of (u64 key, u32 index) pairs.

This is the compute hot-spot of a map task (paper §2.3): sort the input
partition by key. The 90-byte payloads never enter the kernel — the L3
coordinator applies the returned index permutation natively, mirroring the
paper's C++ component which sorts key pointers.

``interpret=True`` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call that the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md). Interpret mode lowers the kernel to plain HLO,
which the Rust runtime compiles and runs via PJRT.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import bitonic


def _sort_kernel(keys_ref, vals_ref, out_keys_ref, out_vals_ref):
    keys = keys_ref[...]
    vals = vals_ref[...]
    keys, vals = bitonic.bitonic_sort_pairs(keys, vals)
    out_keys_ref[...] = keys
    out_vals_ref[...] = vals


def sort_pairs(keys, vals, *, interpret: bool = True):
    """Sort (keys: u64[N], vals: u32[N]) ascending by (key, val).

    N must be a power of two. Returns (sorted_keys, permuted_vals).
    """
    n = keys.shape[0]
    return pl.pallas_call(
        _sort_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.uint64),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
        ),
        interpret=interpret,
    )(keys, vals)


def vmem_bytes(n: int) -> int:
    """Estimated VMEM working set for a block of n records.

    Two resident copies of (u64 key + u32 val) during a compare-exchange
    stage (input + output of the select), i.e. 2 * 12 bytes per record.
    """
    return 2 * 12 * n


def compare_exchange_stages(n: int) -> int:
    """Number of compare-exchange stages for a full sort of n (power of 2)."""
    logn = n.bit_length() - 1
    return logn * (logn + 1) // 2
