"""Pure-jnp/numpy correctness oracles for the L1 Pallas kernels.

These are the CORE correctness signal: every kernel must match its oracle
bit-for-bit (keys) / under the lexicographic-(key, val) order (vals).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def sort_pairs_ref(keys, vals):
    """Ascending lexicographic sort of (key, val) pairs."""
    keys = np.asarray(keys)
    vals = np.asarray(vals)
    order = np.lexsort((vals, keys))
    return jnp.asarray(keys[order]), jnp.asarray(vals[order])


def partition_offsets_ref(sorted_keys, cuts):
    """offs[c] = #{keys < cuts[c]} via numpy searchsorted."""
    sorted_keys = np.asarray(sorted_keys)
    cuts = np.asarray(cuts)
    return jnp.asarray(
        np.searchsorted(sorted_keys, cuts, side="left").astype(np.uint32)
    )


def merge_runs_ref(keys, vals):
    """Merge sorted rows by flattening + lexicographic re-sort (oracle)."""
    keys = np.asarray(keys).reshape(-1)
    vals = np.asarray(vals).reshape(-1)
    return sort_pairs_ref(keys, vals)
