"""L1 Pallas kernels for Exoshuffle-CloudSort's compute hot-spot.

- ``sort``: bitonic sort of (u64 key, u32 payload-index) pairs (map tasks)
- ``merge``: bitonic merge of pre-sorted runs (merge + reduce tasks)
- ``partition``: binary-search partition offsets against range cut points
- ``ref``: pure-jnp/numpy oracles for all of the above
"""

from . import bitonic, merge, partition, ref, sort  # noqa: F401
