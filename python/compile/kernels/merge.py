"""L1 Pallas kernel: merge R sorted runs of (u64 key, u32 index) pairs.

This is the compute hot-spot of merge and reduce tasks (paper §2.3–2.4):
a merge task merges W sorted map blocks; a reduce task merges R/W = 625
merged blocks. The L3 coordinator pads the run count and run length to the
artifact's power-of-two shape with u64::MAX sentinels (which keep every run
sorted and fall to the end of the output), and tree-merges when a task has
more runs than the artifact accepts.

log2(R) rounds of pairwise bitonic merges — O(n · log R · log n) work
versus O(n · log^2 n) for re-sorting from scratch; the Pallas analogue of
the paper's streaming k-way merge (DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import bitonic


def _merge_kernel(keys_ref, vals_ref, out_keys_ref, out_vals_ref):
    keys = keys_ref[...]
    vals = vals_ref[...]
    out_keys, out_vals = bitonic.merge_sorted_runs(keys, vals)
    out_keys_ref[...] = out_keys
    out_vals_ref[...] = out_vals


def merge_runs(keys, vals, *, interpret: bool = True):
    """Merge runs: (keys: u64[R, L], vals: u32[R, L]) -> flat sorted pair.

    Each row must be ascending by (key, val); R and L powers of two.
    """
    r, l = keys.shape
    n = r * l
    return pl.pallas_call(
        _merge_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.uint64),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
        ),
        interpret=interpret,
    )(keys, vals)


def compare_exchange_stages(r: int, l: int) -> int:
    """Stage count for merging r runs of length l (powers of two)."""
    stages = 0
    length = l
    runs = r
    while runs > 1:
        length *= 2
        stages += length.bit_length() - 1
        runs //= 2
    return stages
