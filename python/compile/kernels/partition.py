"""L1 Pallas kernel: partition offsets of a sorted key block.

Given ascending-sorted keys (u64[N], N a power of two) and C interior cut
points, returns offs[c] = |{ i : keys[i] < cuts[c] }| — i.e. the boundary
offsets that slice the sorted block into C+1 partition ranges
(paper §2.2: R = 25 000 equal u64 key ranges, grouped into W worker ranges).

Cut arrays are padded to the artifact's fixed C with u64::MAX by the L3
caller; padded cuts yield offs = number of non-sentinel keys, which the
caller ignores. Sentinel keys (u64::MAX padding of short blocks) are never
counted because ``key < cut`` is false when cut == u64::MAX.

Branchless vectorized binary search over all C cuts simultaneously:
log2(N) rounds, one dynamic gather of C lanes per round.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _partition_kernel(keys_ref, cuts_ref, offs_ref):
    keys = keys_ref[...]
    cuts = cuts_ref[...]
    n = keys.shape[0]
    logn = n.bit_length() - 1
    c = cuts.shape[0]
    # Bitwise binary search: build pos = count of keys < cut, bit by bit.
    pos = jnp.zeros((c,), dtype=jnp.uint32)
    for b in range(logn - 1, -1, -1):
        cand = pos + jnp.uint32(1 << b)
        probe = jnp.take(keys, cand - 1, indices_are_sorted=False)
        pos = jnp.where(probe < cuts, cand, pos)
    # pos <= n-1 so far; the all-keys-below-cut case needs the last element.
    last = keys[n - 1]
    pos = jnp.where((pos == jnp.uint32(n - 1)) & (last < cuts),
                    jnp.uint32(n), pos)
    offs_ref[...] = pos


def partition_offsets(keys, cuts, *, interpret: bool = True):
    """offs[c] = #{keys < cuts[c]} for ascending-sorted keys."""
    c = cuts.shape[0]
    return pl.pallas_call(
        _partition_kernel,
        out_shape=jax.ShapeDtypeStruct((c,), jnp.uint32),
        interpret=interpret,
    )(keys, cuts)
