"""L2: the JAX compute graphs of Exoshuffle-CloudSort's tasks.

Two graphs, each composed from the L1 Pallas kernels and AOT-lowered by
``aot.py`` to HLO text that the Rust runtime executes via PJRT:

- ``sort_and_partition`` — the map-task hot path (paper §2.3): sort one
  input block by key, and compute the offsets that slice the sorted block
  into W worker ranges.
- ``merge_and_partition`` — the merge/reduce-task hot path (paper
  §2.3–2.4): merge R pre-sorted runs and compute partition offsets of the
  result (merge tasks slice into R/W reducer ranges; reduce tasks pass
  sentinel cuts and ignore the offsets).

Everything is shape-static: the L3 coordinator pads records with u64::MAX
sentinel keys and cut arrays with u64::MAX sentinel cuts (see kernel module
docstrings for why sentinels are sound).

Python never runs at request time — these functions exist only to be
lowered once by ``make artifacts``.
"""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels import merge as merge_kernel  # noqa: E402
from .kernels import partition as partition_kernel  # noqa: E402
from .kernels import sort as sort_kernel  # noqa: E402


def sort_and_partition(keys, vals, cuts):
    """Map-task graph.

    Args:
      keys: u64[N] partition keys (N a power of two; padded with u64::MAX).
      vals: u32[N] payload indices (unique; identity iota from the caller).
      cuts: u64[C] interior range cut points (padded with u64::MAX).

    Returns:
      (sorted_keys: u64[N], perm: u32[N], offs: u32[C]) where
      offs[c] = #{keys < cuts[c]}.
    """
    sorted_keys, perm = sort_kernel.sort_pairs(keys, vals)
    offs = partition_kernel.partition_offsets(sorted_keys, cuts)
    return sorted_keys, perm, offs


def merge_and_partition(keys, vals, cuts):
    """Merge/reduce-task graph.

    Args:
      keys: u64[R, L] — R ascending-sorted runs of length L (powers of two,
        sentinel-padded).
      vals: u32[R, L] payload indices, unique across the whole array.
      cuts: u64[C] interior cut points (sentinel-padded).

    Returns:
      (merged_keys: u64[R*L], perm: u32[R*L], offs: u32[C]).
    """
    merged_keys, perm = merge_kernel.merge_runs(keys, vals)
    offs = partition_kernel.partition_offsets(merged_keys, cuts)
    return merged_keys, perm, offs


def sort_and_partition_spec(n: int, c: int):
    """Example-argument specs for AOT lowering of ``sort_and_partition``."""
    return (
        jax.ShapeDtypeStruct((n,), jnp.uint64),
        jax.ShapeDtypeStruct((n,), jnp.uint32),
        jax.ShapeDtypeStruct((c,), jnp.uint64),
    )


def merge_and_partition_spec(r: int, l: int, c: int):
    """Example-argument specs for AOT lowering of ``merge_and_partition``."""
    return (
        jax.ShapeDtypeStruct((r, l), jnp.uint64),
        jax.ShapeDtypeStruct((r, l), jnp.uint32),
        jax.ShapeDtypeStruct((c,), jnp.uint64),
    )
