"""AOT compiler: lower the L2 graphs to HLO text artifacts for Rust/PJRT.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each artifact is one (graph, shape) instantiation; ``manifest.json``
records the full set so the Rust runtime can pick the right executable and
pad inputs to its shape. ``python -m compile.aot --out ../artifacts``.

``--report`` prints a structural perf report per artifact (VMEM footprint,
compare-exchange stage count, HLO op count) — the L1 profile signal used
by EXPERIMENTS.md §Perf (interpret-mode wallclock is not a TPU proxy; we
optimize structure, and XLA-CPU execution speed is measured from Rust).
"""

from __future__ import annotations

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels import merge as merge_kernel  # noqa: E402
from .kernels import sort as sort_kernel  # noqa: E402

# Default artifact set. The Rust runtime tree-merges / chunk-sorts around
# these fixed shapes, so a small set covers every run configuration:
#   - sort_n{n}_c{c}: map-task chunk sort (+ worker-range partition)
#   - merge_r{r}_l{l}_c{c}: merge/reduce-task run merge (+ reducer ranges)
# Small shapes keep unit tests fast; 64Ki-record shapes are the hot-path
# default (VMEM-sized per DESIGN.md §Hardware-Adaptation).
SORT_SHAPES = [
    # (n, c)
    (256, 64),
    (4096, 64),
    (16384, 64),
    (65536, 64),
]
MERGE_SHAPES = [
    # (r, l, c)
    (8, 32, 64),
    (8, 512, 256),
    (16, 4096, 1024),
    (64, 1024, 1024),
]


def to_hlo_text(lowered) -> str:
    """Convert a jax Lowered to XLA HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_sort(n: int, c: int) -> str:
    spec = model.sort_and_partition_spec(n, c)
    return to_hlo_text(jax.jit(model.sort_and_partition).lower(*spec))


def lower_merge(r: int, l: int, c: int) -> str:
    spec = model.merge_and_partition_spec(r, l, c)
    return to_hlo_text(jax.jit(model.merge_and_partition).lower(*spec))


def build(out_dir: str, report: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "version": 1, "sort": [], "merge": []}
    for n, c in SORT_SHAPES:
        name = f"sort_n{n}_c{c}.hlo.txt"
        text = lower_sort(n, c)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        entry = {"file": name, "n": n, "c": c}
        manifest["sort"].append(entry)
        if report:
            _report("sort", entry, text,
                    stages=sort_kernel.compare_exchange_stages(n),
                    vmem=sort_kernel.vmem_bytes(n))
    for r, l, c in MERGE_SHAPES:
        name = f"merge_r{r}_l{l}_c{c}.hlo.txt"
        text = lower_merge(r, l, c)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        entry = {"file": name, "r": r, "l": l, "c": c}
        manifest["merge"].append(entry)
        if report:
            _report("merge", entry, text,
                    stages=merge_kernel.compare_exchange_stages(r, l),
                    vmem=2 * 12 * r * l)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def _hlo_op_count(text: str) -> int:
    return sum(1 for line in text.splitlines() if " = " in line)


def _report(kind: str, entry: dict, text: str, stages: int, vmem: int):
    print(
        f"[aot] {kind} {entry}: stages={stages} "
        f"vmem={vmem / 1024:.0f}KiB hlo_ops={_hlo_op_count(text)} "
        f"hlo_bytes={len(text)}"
    )


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts",
                        help="artifact output directory")
    parser.add_argument("--report", action="store_true",
                        help="print structural perf report per artifact")
    args = parser.parse_args()
    manifest = build(args.out, report=args.report)
    n_artifacts = len(manifest["sort"]) + len(manifest["merge"])
    print(f"[aot] wrote {n_artifacts} artifacts + manifest.json to {args.out}")


if __name__ == "__main__":
    main()
