"""L1 kernel correctness: Pallas kernels vs pure-numpy oracles.

This is the CORE correctness signal for the compute hot path. Hypothesis
sweeps shapes, dtype corner values (0, u64::MAX sentinels, duplicates) and
adversarial key distributions; every case must match the oracle exactly.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitonic, merge, partition, ref, sort

U64_MAX = np.uint64(0xFFFFFFFFFFFFFFFF)


def _keys(rng, n, dist):
    if dist == "uniform":
        return rng.integers(0, 2**64, n, dtype=np.uint64)
    if dist == "lowcard":  # many duplicates
        return rng.integers(0, 8, n).astype(np.uint64)
    if dist == "sorted":
        return np.sort(rng.integers(0, 2**64, n, dtype=np.uint64))
    if dist == "reversed":
        return np.sort(rng.integers(0, 2**64, n, dtype=np.uint64))[::-1].copy()
    if dist == "extremes":  # sentinel-heavy
        return rng.choice(
            np.array([0, 1, 2**63, U64_MAX - 1, U64_MAX], dtype=np.uint64), n
        )
    raise ValueError(dist)


DISTS = ["uniform", "lowcard", "sorted", "reversed", "extremes"]


class TestSortKernel:
    @pytest.mark.parametrize("n", [2, 4, 64, 256, 1024])
    @pytest.mark.parametrize("dist", DISTS)
    def test_matches_ref(self, n, dist):
        rng = np.random.default_rng(n * 31 + DISTS.index(dist))
        keys = _keys(rng, n, dist)
        vals = np.arange(n, dtype=np.uint32)
        sk, sv = sort.sort_pairs(jnp.asarray(keys), jnp.asarray(vals))
        rk, rv = ref.sort_pairs_ref(keys, vals)
        np.testing.assert_array_equal(np.asarray(sk), np.asarray(rk))
        np.testing.assert_array_equal(np.asarray(sv), np.asarray(rv))

    def test_permutation_is_valid(self):
        rng = np.random.default_rng(7)
        keys = _keys(rng, 512, "uniform")
        vals = np.arange(512, dtype=np.uint32)
        sk, sv = sort.sort_pairs(jnp.asarray(keys), jnp.asarray(vals))
        sv = np.asarray(sv)
        assert sorted(sv.tolist()) == list(range(512))
        # applying the permutation to keys reproduces the sorted keys
        np.testing.assert_array_equal(keys[sv], np.asarray(sk))

    def test_sentinel_padding_sorts_to_end(self):
        rng = np.random.default_rng(11)
        keys = _keys(rng, 100, "uniform")
        padded = np.concatenate([keys, np.full(28, U64_MAX, dtype=np.uint64)])
        vals = np.arange(128, dtype=np.uint32)
        sk, sv = sort.sort_pairs(jnp.asarray(padded), jnp.asarray(vals))
        sk, sv = np.asarray(sk), np.asarray(sv)
        # all sentinels land in the tail (some real keys could be MAX too,
        # but not with this seed)
        assert (sk[100:] == U64_MAX).all()
        assert (np.sort(sv[100:]) == np.arange(100, 128)).all()

    @settings(max_examples=25, deadline=None)
    @given(
        logn=st.integers(min_value=1, max_value=9),
        seed=st.integers(min_value=0, max_value=2**31),
        dist=st.sampled_from(DISTS),
    )
    def test_hypothesis_sweep(self, logn, seed, dist):
        n = 1 << logn
        rng = np.random.default_rng(seed)
        keys = _keys(rng, n, dist)
        vals = rng.permutation(n).astype(np.uint32)
        sk, sv = sort.sort_pairs(jnp.asarray(keys), jnp.asarray(vals))
        rk, rv = ref.sort_pairs_ref(keys, vals)
        np.testing.assert_array_equal(np.asarray(sk), np.asarray(rk))
        np.testing.assert_array_equal(np.asarray(sv), np.asarray(rv))

    def test_non_power_of_two_rejected(self):
        keys = jnp.zeros((100,), dtype=jnp.uint64)
        vals = jnp.zeros((100,), dtype=jnp.uint32)
        with pytest.raises(ValueError):
            sort.sort_pairs(keys, vals)


class TestPartitionKernel:
    @pytest.mark.parametrize("n", [2, 256, 4096])
    @pytest.mark.parametrize("c", [1, 16, 64])
    def test_matches_ref(self, n, c):
        rng = np.random.default_rng(n + c)
        keys = np.sort(rng.integers(0, 2**64, n, dtype=np.uint64))
        cuts = np.sort(rng.integers(0, 2**64, c, dtype=np.uint64))
        offs = partition.partition_offsets(jnp.asarray(keys), jnp.asarray(cuts))
        roffs = ref.partition_offsets_ref(keys, cuts)
        np.testing.assert_array_equal(np.asarray(offs), np.asarray(roffs))

    def test_cut_below_all_keys(self):
        keys = np.sort(np.random.default_rng(1).integers(
            100, 2**64, 64, dtype=np.uint64))
        cuts = np.array([0, 1, 50], dtype=np.uint64)
        offs = partition.partition_offsets(jnp.asarray(keys), jnp.asarray(cuts))
        np.testing.assert_array_equal(np.asarray(offs), [0, 0, 0])

    def test_cut_above_all_keys(self):
        keys = np.sort(np.random.default_rng(2).integers(
            0, 2**32, 64, dtype=np.uint64))
        cuts = np.array([2**40, U64_MAX], dtype=np.uint64)
        offs = partition.partition_offsets(jnp.asarray(keys), jnp.asarray(cuts))
        np.testing.assert_array_equal(np.asarray(offs), [64, 64])

    def test_sentinel_cuts_ignore_sentinel_keys(self):
        # padded block: 50 real keys + 14 sentinels; sentinel cut (u64::MAX)
        # must report only the 50 real keys (key < MAX).
        rng = np.random.default_rng(3)
        keys = np.sort(rng.integers(0, 2**63, 50, dtype=np.uint64))
        padded = np.concatenate([keys, np.full(14, U64_MAX, dtype=np.uint64)])
        cuts = np.full(8, U64_MAX, dtype=np.uint64)
        offs = partition.partition_offsets(
            jnp.asarray(padded), jnp.asarray(cuts))
        np.testing.assert_array_equal(np.asarray(offs), np.full(8, 50))

    def test_cuts_equal_to_keys_are_exclusive(self):
        keys = np.array([10, 20, 20, 30], dtype=np.uint64)
        cuts = np.array([10, 20, 21, 30, 31], dtype=np.uint64)
        offs = partition.partition_offsets(jnp.asarray(keys), jnp.asarray(cuts))
        np.testing.assert_array_equal(np.asarray(offs), [0, 1, 3, 3, 4])

    @settings(max_examples=25, deadline=None)
    @given(
        logn=st.integers(min_value=1, max_value=10),
        c=st.integers(min_value=1, max_value=80),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_sweep(self, logn, c, seed):
        n = 1 << logn
        rng = np.random.default_rng(seed)
        keys = np.sort(rng.integers(0, 2**64, n, dtype=np.uint64))
        cuts = np.sort(rng.integers(0, 2**64, c, dtype=np.uint64))
        offs = partition.partition_offsets(jnp.asarray(keys), jnp.asarray(cuts))
        roffs = ref.partition_offsets_ref(keys, cuts)
        np.testing.assert_array_equal(np.asarray(offs), np.asarray(roffs))


class TestMergeKernel:
    @pytest.mark.parametrize("r,l", [(2, 4), (4, 16), (8, 32), (16, 64)])
    @pytest.mark.parametrize("dist", ["uniform", "lowcard", "extremes"])
    def test_matches_ref(self, r, l, dist):
        rng = np.random.default_rng(r * l)
        keys = np.sort(
            _keys(rng, r * l, dist).reshape(r, l), axis=1)
        vals = rng.permutation(r * l).astype(np.uint32).reshape(r, l)
        # rows must be sorted by (key, val): sort vals within equal keys
        order = np.lexsort((vals, keys), axis=1)
        keys = np.take_along_axis(keys, order, axis=1)
        vals = np.take_along_axis(vals, order, axis=1)
        ok, ov = merge.merge_runs(jnp.asarray(keys), jnp.asarray(vals))
        gk, gv = ref.merge_runs_ref(keys, vals)
        np.testing.assert_array_equal(np.asarray(ok), np.asarray(gk))
        np.testing.assert_array_equal(np.asarray(ov), np.asarray(gv))

    def test_sentinel_runs(self):
        # padding a 3-run merge to r=4 with an all-sentinel run
        rng = np.random.default_rng(5)
        keys = np.sort(rng.integers(0, 2**63, (3, 16), dtype=np.uint64), axis=1)
        pad = np.full((1, 16), U64_MAX, dtype=np.uint64)
        keys = np.vstack([keys, pad])
        vals = np.arange(64, dtype=np.uint32).reshape(4, 16)
        ok, ov = merge.merge_runs(jnp.asarray(keys), jnp.asarray(vals))
        ok = np.asarray(ok)
        assert (ok[48:] == U64_MAX).all()
        assert (ok[:48] < U64_MAX).all()
        assert (np.diff(ok.astype(object)) >= 0).all()

    @settings(max_examples=20, deadline=None)
    @given(
        logr=st.integers(min_value=0, max_value=4),
        logl=st.integers(min_value=1, max_value=6),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_sweep(self, logr, logl, seed):
        r, l = 1 << logr, 1 << logl
        rng = np.random.default_rng(seed)
        keys = rng.integers(0, 2**64, (r, l), dtype=np.uint64)
        vals = rng.permutation(r * l).astype(np.uint32).reshape(r, l)
        order = np.lexsort((vals, keys), axis=1)
        keys = np.take_along_axis(keys, order, axis=1)
        vals = np.take_along_axis(vals, order, axis=1)
        ok, ov = merge.merge_runs(jnp.asarray(keys), jnp.asarray(vals))
        gk, gv = ref.merge_runs_ref(keys, vals)
        np.testing.assert_array_equal(np.asarray(ok), np.asarray(gk))
        np.testing.assert_array_equal(np.asarray(ov), np.asarray(gv))


class TestBitonicPrimitives:
    def test_compare_exchange_ascending(self):
        keys = jnp.asarray(np.array([4, 1, 3, 2], dtype=np.uint64))
        vals = jnp.asarray(np.arange(4, dtype=np.uint32))
        k, v = bitonic.compare_exchange(keys, vals, 1, None)
        np.testing.assert_array_equal(np.asarray(k), [1, 4, 2, 3])
        np.testing.assert_array_equal(np.asarray(v), [1, 0, 3, 2])

    def test_compare_exchange_ties_break_on_vals(self):
        keys = jnp.asarray(np.array([5, 5], dtype=np.uint64))
        vals = jnp.asarray(np.array([9, 3], dtype=np.uint32))
        k, v = bitonic.compare_exchange(keys, vals, 1, None)
        np.testing.assert_array_equal(np.asarray(v), [3, 9])

    def test_log2_rejects_non_powers(self):
        for bad in [0, 3, 6, 100]:
            with pytest.raises(ValueError):
                bitonic._log2(bad)

    def test_stage_count_formulas(self):
        assert sort.compare_exchange_stages(2) == 1
        assert sort.compare_exchange_stages(1024) == 55
        assert merge.compare_exchange_stages(1, 8) == 0
        assert merge.compare_exchange_stages(2, 8) == 4
        # merging happens in log2(r) rounds of growing sequences
        assert merge.compare_exchange_stages(4, 4) == 3 + 4
