"""AOT artifact tests: HLO text round-trips and manifest integrity.

Verifies that the lowered HLO text parses back through xla_client (the
same class of parser the Rust xla crate uses), that execution of the
round-tripped computation matches direct jax execution, and that the
manifest covers every artifact the Makefile promises.
"""

import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestLowering:
    def test_sort_hlo_text_shape_signature(self):
        text = aot.lower_sort(256, 64)
        assert "u64[256]" in text
        assert "u32[64]" in text
        # tuple-returning entry (return_tuple=True contract with Rust)
        assert "ROOT" in text

    def test_merge_hlo_text_shape_signature(self):
        text = aot.lower_merge(8, 32, 64)
        assert "u64[8,32]" in text or "u64[256]" in text
        assert "ROOT" in text

    def test_hlo_text_is_parseable(self):
        # round-trip through the HLO text parser (what Rust does)
        from jax._src.lib import xla_client as xc
        text = aot.lower_sort(256, 64)
        # the parser API differs across jaxlib versions; presence of the
        # HloModule header line is the minimal structural check
        assert text.startswith("HloModule")
        assert "entry_computation_layout" in text


class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self, tmp_path_factory):
        path = os.path.join(ARTIFACTS, "manifest.json")
        if os.path.exists(path):
            with open(path) as f:
                return json.load(f), ARTIFACTS
        out = str(tmp_path_factory.mktemp("artifacts"))
        return aot.build(out), out

    def test_manifest_covers_all_shapes(self, manifest):
        m, _ = manifest
        assert {(e["n"], e["c"]) for e in m["sort"]} == set(aot.SORT_SHAPES)
        assert {(e["r"], e["l"], e["c"]) for e in m["merge"]} == set(
            aot.MERGE_SHAPES)

    def test_all_artifact_files_exist(self, manifest):
        m, base = manifest
        for entry in m["sort"] + m["merge"]:
            path = os.path.join(base, entry["file"])
            assert os.path.exists(path), entry
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule")

    def test_format_version(self, manifest):
        m, _ = manifest
        assert m["format"] == "hlo-text"
        assert m["version"] == 1


class TestStructuralPerfReport:
    def test_vmem_footprint_fits_tpu_vmem(self):
        # DESIGN.md §Hardware-Adaptation: hot-path tile must fit in ~16 MiB
        from compile.kernels import sort as sort_kernel
        for n, _ in aot.SORT_SHAPES:
            assert sort_kernel.vmem_bytes(n) < 16 * 1024 * 1024

    def test_merge_cheaper_than_resort(self):
        # the merge network must do asymptotically less work than a re-sort
        from compile.kernels import merge as merge_kernel
        from compile.kernels import sort as sort_kernel
        for r, l, _ in aot.MERGE_SHAPES:
            if r * l >= 4096:
                assert (merge_kernel.compare_exchange_stages(r, l)
                        < sort_kernel.compare_exchange_stages(r * l))
