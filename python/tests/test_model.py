"""L2 graph tests: sort_and_partition / merge_and_partition composition.

These exercise exactly the contract the Rust runtime relies on: sentinel
padding semantics, permutation validity, and offset/slice agreement.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

U64_MAX = np.uint64(0xFFFFFFFFFFFFFFFF)


def _worker_cuts(w: int, c: int) -> np.ndarray:
    """Interior cut points for w equal u64 ranges, sentinel-padded to c."""
    step = 2**64 // w
    cuts = np.array([(i + 1) * step for i in range(w - 1)], dtype=np.uint64)
    pad = np.full(c - len(cuts), U64_MAX, dtype=np.uint64)
    return np.concatenate([cuts, pad])


class TestSortAndPartition:
    @pytest.mark.parametrize("n,w", [(256, 4), (1024, 8), (256, 40)])
    def test_end_to_end(self, n, w):
        rng = np.random.default_rng(n + w)
        n_valid = n - n // 8
        keys = rng.integers(0, 2**64, n_valid, dtype=np.uint64)
        padded = np.concatenate(
            [keys, np.full(n - n_valid, U64_MAX, dtype=np.uint64)])
        vals = np.arange(n, dtype=np.uint32)
        cuts = _worker_cuts(w, 64)
        sk, perm, offs = model.sort_and_partition(
            jnp.asarray(padded), jnp.asarray(vals), jnp.asarray(cuts))
        sk, perm, offs = map(np.asarray, (sk, perm, offs))
        # keys sorted, permutation valid
        assert (np.diff(sk.astype(object)) >= 0).all()
        np.testing.assert_array_equal(padded[perm], sk)
        # slice [offs[i-1], offs[i]) contains exactly the keys in range i
        bounds = np.concatenate([[0], cuts[: w - 1], [2**64]])
        full_offs = np.concatenate([[0], offs[: w - 1], [n_valid]])
        for i in range(w):
            lo, hi = int(full_offs[i]), int(full_offs[i + 1])
            seg = sk[lo:hi]
            assert (seg.astype(object) >= int(bounds[i])).all()
            assert (seg.astype(object) < int(bounds[i + 1])).all()
        # every real key accounted for
        assert int(full_offs[-1]) == n_valid

    def test_matches_ref_pipeline(self):
        rng = np.random.default_rng(77)
        n = 512
        keys = rng.integers(0, 2**64, n, dtype=np.uint64)
        vals = np.arange(n, dtype=np.uint32)
        cuts = _worker_cuts(8, 64)
        sk, perm, offs = model.sort_and_partition(
            jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(cuts))
        rk, rv = ref.sort_pairs_ref(keys, vals)
        roffs = ref.partition_offsets_ref(np.asarray(rk), cuts)
        np.testing.assert_array_equal(np.asarray(sk), np.asarray(rk))
        np.testing.assert_array_equal(np.asarray(perm), np.asarray(rv))
        np.testing.assert_array_equal(np.asarray(offs), np.asarray(roffs))


class TestMergeAndPartition:
    def test_end_to_end(self):
        rng = np.random.default_rng(9)
        r, l = 8, 64
        keys = np.sort(rng.integers(0, 2**64, (r, l), dtype=np.uint64), axis=1)
        vals = np.arange(r * l, dtype=np.uint32).reshape(r, l)
        cuts = _worker_cuts(16, 64)
        mk, perm, offs = model.merge_and_partition(
            jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(cuts))
        mk, perm, offs = map(np.asarray, (mk, perm, offs))
        assert (np.diff(mk.astype(object)) >= 0).all()
        np.testing.assert_array_equal(keys.reshape(-1)[perm], mk)
        roffs = ref.partition_offsets_ref(mk, cuts)
        np.testing.assert_array_equal(offs, np.asarray(roffs))

    @settings(max_examples=10, deadline=None)
    @given(
        logr=st.integers(min_value=1, max_value=3),
        logl=st.integers(min_value=2, max_value=6),
        w=st.integers(min_value=2, max_value=32),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_sweep(self, logr, logl, w, seed):
        r, l = 1 << logr, 1 << logl
        rng = np.random.default_rng(seed)
        keys = np.sort(rng.integers(0, 2**64, (r, l), dtype=np.uint64), axis=1)
        vals = rng.permutation(r * l).astype(np.uint32).reshape(r, l)
        order = np.lexsort((vals, keys), axis=1)
        keys = np.take_along_axis(keys, order, axis=1)
        vals = np.take_along_axis(vals, order, axis=1)
        cuts = _worker_cuts(w, 64)
        mk, perm, offs = model.merge_and_partition(
            jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(cuts))
        gk, gv = ref.merge_runs_ref(keys, vals)
        np.testing.assert_array_equal(np.asarray(mk), np.asarray(gk))
        np.testing.assert_array_equal(np.asarray(perm), np.asarray(gv))
